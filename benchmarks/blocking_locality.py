"""Paper Fig 1 analogue: naive vs GotoBLAS-blocked data movement.

The paper measures L1 cache miss rate (23–36% naive → <5% ulmBLAS). The TPU
analogue is HBM→VMEM traffic: a naive schedule re-streams whole operands per
output tile, the blocked CAMP schedule streams each panel once per k-block
pass. We report the modeled traffic ratio and the implied HBM-bound time.
"""
from __future__ import annotations

from benchmarks.common import HBM_BW, csv_row
from repro.core.blocking import choose_blocks

SHAPES = [(512, 512, 512), (1024, 1024, 1024), (4096, 4096, 4096),
          (12544, 64, 147), (196, 512, 4608)]   # + two ResNet/VGG layers


def traffic(m, n, k, bm, bn, bk, a_bytes=1, b_bytes=1, out_bytes=4):
    """HBM bytes for a (bm,bn,bk)-blocked GEMM (A re-read per n-panel, B
    re-read per m-panel — the GotoBLAS trade)."""
    n_panels_n = -(-n // bn)
    n_panels_m = -(-m // bm)
    a_traffic = m * k * a_bytes * n_panels_n
    b_traffic = k * n * b_bytes * n_panels_m
    return a_traffic + b_traffic + m * n * out_bytes


def rows():
    out = []
    for (m, n, k) in SHAPES:
        naive = traffic(m, n, k, 8, 8, k)            # tiny unblocked tiles
        blk = choose_blocks(m, n, k)
        blocked = traffic(m, n, k, blk.bm, blk.bn, blk.bk)
        ideal = m * k + k * n + 4 * m * n            # every byte once
        out.append(csv_row(
            f"fig1_traffic_{m}x{n}x{k}",
            blocked / HBM_BW * 1e6,
            f"naive_bytes={naive:.3g};blocked_bytes={blocked:.3g};"
            f"ideal={ideal:.3g};reduction={naive / blocked:.1f}x;"
            f"blocked_vs_ideal={blocked / ideal:.2f}"))
    out.append(csv_row("fig1_paper_claim", 0.0,
                       "naive_L1_miss=23-36%;ulmBLAS<5%"))
    return out


if __name__ == "__main__":
    print("\n".join(rows()))
