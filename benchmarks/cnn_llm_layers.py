"""Paper Figs 12/13/14 + Table 3: per-layer GEMM speedups.

* Fig 12: square matmul sizes 32…1024 (RISC-V SMM sweep).
* Fig 13 / Table 3: CNN layers cast to GEMM (AlexNet, ResNet, VGG, MobileNet).
* Fig 14: LLM self-attention / feed-forward layer GEMMs (BERT-B/L, GPT-2L,
  GPT-3S) — the paper evaluates the matmuls of SA and FF blocks at seq 512.

Derived metric per shape: v5e-modeled CAMP speedup over fp32 (the TPU-native
analogue of the paper's clock-cycle ratios) + measured XLA-CPU time of the
real jitted op for the smaller shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, modeled_gemm_s, time_call
from repro.core import camp

# Table 3 of the paper: (m, n, k) per layer.
TABLE3 = {
    "alexnet": [(169, 256, 3456), (169, 384, 2304), (169, 384, 3456),
                (3025, 96, 363), (729, 256, 2400)],
    "smm": [(32, 32, 32), (64, 64, 64), (128, 128, 128), (256, 256, 256),
            (512, 512, 512), (1024, 1024, 1024)],
    "resnet": [(12544, 64, 147), (196, 256, 1152), (196, 256, 2304),
               (3136, 64, 576), (49, 512, 2304), (49, 512, 4608),
               (784, 128, 1152), (784, 128, 576)],
    "vgg": [(12544, 128, 1152), (12544, 128, 576), (196, 512, 4608),
            (3136, 256, 1152), (3136, 256, 2304), (50176, 64, 27),
            (50176, 64, 576), (784, 512, 2304), (784, 512, 4608)],
    "mobilenet": [(12544, 32, 27), (12544, 64, 32), (196, 512, 256),
                  (196, 512, 512), (3136, 128, 128), (3136, 128, 64),
                  (49, 1024, 1024), (49, 1024, 512), (784, 256, 128),
                  (784, 256, 256)],
}

# LLM layer GEMMs at seq 512 (d = hidden, ff = 4d): SA = qkv+proj+scores,
# FF = two matmuls. We benchmark the dominant (seq×d)×(d×n) shapes.
LLM = {
    "bert_base": 768, "bert_large": 1024, "gpt2_large": 1280,
    "gpt3_small": 768,
}
SEQ = 512


def _llm_shapes(d):
    return {
        "sa": [(SEQ, 3 * d, d), (SEQ, d, d)],        # qkv pack + out proj
        "ff": [(SEQ, 4 * d, d), (SEQ, d, 4 * d)],
    }


def _bench_shape(m, n, k, measure: bool):
    model8 = modeled_gemm_s(m, n, k, "fp32") / modeled_gemm_s(m, n, k, "w8a8")
    model4 = modeled_gemm_s(m, n, k, "fp32") / modeled_gemm_s(m, n, k, "w4a4")
    t_us = 0.0
    if measure:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        wq = camp.prepare_weight(w, "w8a8")
        f = jax.jit(lambda a: camp.camp_matmul(a, wq, qmode="w8a8", impl="xla"))
        t_us = time_call(f, x, reps=3, warmup=1) * 1e6
    return t_us, model8, model4


def rows(measure_limit: int = 2 ** 22):
    out = []
    for net, shapes in TABLE3.items():
        sp8, sp4 = [], []
        for i, (m, n, k) in enumerate(shapes):
            t_us, m8, m4 = _bench_shape(m, n, k, measure=m * n * k < measure_limit)
            sp8.append(m8)
            sp4.append(m4)
            out.append(csv_row(f"fig13_{net}_l{i + 1}_{m}x{n}x{k}", t_us,
                               f"modeled_w8a8={m8:.1f}x;modeled_w4a4={m4:.1f}x"))
        out.append(csv_row(f"fig13_{net}_avg", 0.0,
                           f"modeled_w8a8={np.mean(sp8):.1f}x;"
                           f"modeled_w4a4={np.mean(sp4):.1f}x"))
    for name, d in LLM.items():
        for blk, shapes in _llm_shapes(d).items():
            for (m, n, k) in shapes:
                t_us, m8, m4 = _bench_shape(m, n, k, measure=m * n * k < measure_limit)
                out.append(csv_row(f"fig14_{name}_{blk}_{m}x{n}x{k}", t_us,
                                   f"modeled_w8a8={m8:.1f}x;modeled_w4a4={m4:.1f}x"))
    return out


if __name__ == "__main__":
    print("\n".join(rows()))
