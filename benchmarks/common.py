"""Shared benchmark utilities: wall-clock timing + the v5e analytic model.

Two kinds of numbers are reported everywhere:
* ``measured`` — median wall-time of the jitted op on THIS host (XLA-CPU).
  CPU int8 throughput does not resemble TPU MXU behaviour; measured numbers
  validate correctness-at-speed, not the paper's claim.
* ``modeled``  — v5e roofline time: max(FLOPs/peak(dtype), bytes/HBM_bw).
  This is the TPU-native analogue of the paper's tables (their gem5/RTL
  numbers are modeled for *their* hardware too).
"""
from __future__ import annotations

import time

import jax
import numpy as np

PEAK_BF16 = 197e12     # FLOP/s per v5e chip
PEAK_INT8 = 394e12     # MXU int8 rate (2× bf16)
HBM_BW = 819e9         # B/s


def time_call(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median seconds per call of a jitted fn (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gemm_bytes(m: int, n: int, k: int, a_bytes: float, b_bytes: float,
               out_bytes: int = 4, scales: bool = False) -> float:
    b = m * k * a_bytes + k * n * b_bytes + m * n * out_bytes
    if scales:
        b += 4 * (m + n)
    return b


def modeled_gemm_s(m: int, n: int, k: int, mode: str) -> float:
    """v5e time for one (M,N,K) GEMM under a CAMP quantization mode."""
    flops = 2.0 * m * n * k
    if mode == "fp32":
        return max(flops / (PEAK_BF16 / 2), gemm_bytes(m, n, k, 4, 4) / HBM_BW)
    if mode == "bf16":
        return max(flops / PEAK_BF16, gemm_bytes(m, n, k, 2, 2, 2) / HBM_BW)
    if mode == "w8a8":
        return max(flops / PEAK_INT8,
                   gemm_bytes(m, n, k, 1, 1, 2, scales=True) / HBM_BW)
    if mode == "w4a8":
        return max(flops / PEAK_INT8,
                   gemm_bytes(m, n, k, 1, 0.5, 2, scales=True) / HBM_BW)
    if mode == "w4a4":
        # int4 MXU path ≈ 2× int8 rate on CAMP-style hardware (the paper's
        # hybrid multiplier); v5e+ int4 support approximated the same way.
        return max(flops / (2 * PEAK_INT8),
                   gemm_bytes(m, n, k, 0.5, 0.5, 2, scales=True) / HBM_BW)
    raise ValueError(mode)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
