"""Decode serving: dense-bf16 vs dense-int8 vs paged-int8 KV caches.

Two numbers per (cache kind, batch), following benchmarks/common.py:

* measured — wall-clock tokens/s of the real serving path on THIS host
  (XLA-CPU): the dense slab loop for the dense kinds, the
  continuous-batching engine + paged-attention reference for paged-int8.
  CPU numbers validate correctness-at-speed, not the roofline claim.
* modeled — v5e HBM bytes per decode step. Decode attention re-reads the
  cache every token, so bytes/step is the roofline term that matters:
  dense kinds stream the whole (B, max_len) slab (bf16: 2 B/elt, int8:
  1 B/elt + per-page scales); paged-int8 streams only the pages sequences
  actually occupy (block-table gather) plus the one-page requantize
  write-back per appended token.

Emits ``BENCH_decode.json`` at the repo root so the serving-roofline
trajectory is recorded run over run. The headline acceptance ratio is
``paged-int8 / dense-bf16`` modeled bytes at batch 8.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import csv_row

BATCHES = (1, 8, 32)
PROMPT = 32
STEPS = 8
MAX_LEN = 256           # dense slab allocation (what the slab path streams)
PAGE_SIZE = 16

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_decode.json")


def _cfg():
    from repro.configs import get_config
    return get_config("qwen2-0.5b", n_layers=4, d_model=256, n_heads=4,
                      n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8192,
                      max_seq_len=MAX_LEN)


def modeled_bytes_step(cfg, batch: int, kind: str, *, mean_len: float,
                       page_size: int = PAGE_SIZE) -> float:
    """v5e HBM cache traffic for ONE ragged decode step (all layers, k+v)."""
    kv, hd, nl = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    per_tok = kv * hd                                 # elements per (k or v)
    if kind == "dense-bf16":
        read = batch * nl * 2 * per_tok * MAX_LEN * 2
        write = batch * nl * 2 * per_tok * 2          # append one token
    elif kind == "dense-int8":
        scales = batch * nl * 2 * kv * (MAX_LEN // page_size) * 4
        read = batch * nl * 2 * per_tok * MAX_LEN * 1 + scales
        # append requantizes the touched page in place
        write = batch * nl * 2 * (per_tok * page_size * 1 + kv * 4)
    elif kind == "paged-int8":
        pages = mean_len / page_size + 0.5            # half-empty last page
        read = batch * nl * 2 * (per_tok * page_size * 1 + kv * 4) * pages
        read += batch * nl * np.ceil(mean_len / page_size) * 4  # block table
        write = batch * nl * 2 * (per_tok * page_size * 1 + kv * 4)
    else:
        raise ValueError(kind)
    return float(read + write)


def _measure_tok_s(cfg, params, batch: int, kind: str) -> float:
    import jax.numpy as jnp

    from repro.serving.engine import _generate_dense, generate
    prompt = jax.random.randint(jax.random.PRNGKey(batch), (batch, PROMPT),
                                0, cfg.vocab_size)
    import time
    if kind == "paged-int8":
        call = lambda: generate(params, cfg, prompt, steps=STEPS,  # noqa: E731
                                kv_dtype="int8", page_size=PAGE_SIZE)
    else:
        kv_dtype = "int8" if kind == "dense-int8" else None
        call = lambda: _generate_dense(  # noqa: E731
            params, cfg, prompt, steps=STEPS, key=None, sample="greedy",
            temperature=1.0, max_len=MAX_LEN, kv_dtype=kv_dtype)
    jax.block_until_ready(call())          # warm (compile/trace)
    t0 = time.perf_counter()
    toks = call()
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    return batch * STEPS / dt


def rows():
    from repro.models import init_params
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mean_len = PROMPT + STEPS / 2
    report = {"bench": "decode_serving", "prompt": PROMPT, "steps": STEPS,
              "max_len": MAX_LEN, "page_size": PAGE_SIZE, "batches": []}
    for batch in BATCHES:
        entry = {"batch": batch, "kinds": {}}
        base = modeled_bytes_step(cfg, batch, "dense-bf16", mean_len=mean_len)
        for kind in ("dense-bf16", "dense-int8", "paged-int8"):
            by = modeled_bytes_step(cfg, batch, kind, mean_len=mean_len)
            tok_s = _measure_tok_s(cfg, params, batch, kind)
            entry["kinds"][kind] = {
                "measured_tok_s": tok_s,
                "modeled_hbm_bytes_step": by,
                "ratio_vs_dense_bf16": by / base,
            }
            yield csv_row(
                f"decode_serving/b{batch}/{kind}", 1e6 / tok_s,
                f"{tok_s:.1f} tok/s; modeled {by / 1e6:.3f} MB/step "
                f"(x{by / base:.3f} of dense-bf16)")
        report["batches"].append(entry)
    b8 = next(e for e in report["batches"] if e["batch"] == 8)
    ratio = b8["kinds"]["paged-int8"]["ratio_vs_dense_bf16"]
    report["paged_int8_vs_dense_bf16_at_b8"] = ratio
    with open(_JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    yield f"# paged-int8 / dense-bf16 modeled bytes at b8: {ratio:.3f}"
    yield f"# wrote {os.path.normpath(_JSON_PATH)}"
