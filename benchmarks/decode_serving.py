"""Decode serving: dense-bf16 vs dense-int8 vs paged-int8 KV caches, plus
prefix sharing and chunked paged prefill.

Per (cache kind, batch), following benchmarks/common.py:

* measured — wall-clock tokens/s of the real serving path on THIS host
  (XLA-CPU): the dense slab loop for the dense kinds, the
  continuous-batching engine + paged-attention reference for paged-int8.
  CPU numbers validate correctness-at-speed, not the roofline claim.
* modeled — v5e HBM bytes per decode step. Decode attention re-reads the
  cache every token, so bytes/step is the roofline term that matters:
  dense kinds stream the whole (B, max_len) slab (bf16: 2 B/elt, int8:
  1 B/elt + per-page scales); paged-int8 streams only the pages sequences
  actually occupy (block-table gather) plus the one-page requantize
  write-back per appended token.

Three serving-regime sections ride along:

* prefix sharing — N requests with a common P-token prefix admitted
  through the engine's trie: shared physical pages vs the N·P/page_size an
  unshared pool would burn.
* chunked paged prefill — engine prefill throughput (tokens straight into
  int8 pages, no dense staging slab) and the pages touched.
* tensor parallel (``--mesh N`` / ``REPRO_BENCH_MESH=N``) — the head-sharded
  serving stack: per-device HBM cache bytes/step (the paged-int8 stream
  divided over the model axis) and the estimated collective bytes/token of
  the two row-parallel all-reduces per layer (f32 wire vs the int8-
  compressed ``quantized_psum``); measured engine tok/s on a real mesh when
  the host exposes ≥ N devices (e.g. under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
* speculative decoding (``--spec`` / ``REPRO_BENCH_SPEC=1``) — draft–verify
  with the model-free n-gram drafter on a repetitive-prompt workload:
  measured acceptance rate, mean tokens per verify step, greedy parity vs
  the non-speculative engine, and the modeled weight-stream bytes per token
  (the γ+1-row verify panel streams the quantized weights once for up to
  γ+1 emitted tokens — the memory-roofline win).

Emits ``BENCH_decode.json`` at the repo root so the serving-roofline
trajectory is recorded run over run. The headline acceptance ratio is
``paged-int8 / dense-bf16`` modeled bytes at batch 8. Set
``REPRO_BENCH_TINY=1`` for a seconds-scale smoke run (CI) that skips the
JSON write.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import csv_row

_TINY = bool(int(os.environ.get("REPRO_BENCH_TINY", "0")))
_MESH_TP = int(os.environ.get("REPRO_BENCH_MESH", "0"))
_SPEC = bool(int(os.environ.get("REPRO_BENCH_SPEC", "1")))

BATCHES = (1, 2) if _TINY else (1, 8, 32)
PROMPT = 8 if _TINY else 32
STEPS = 2 if _TINY else 8
MAX_LEN = 64 if _TINY else 256  # dense slab allocation (what the slab streams)
PAGE_SIZE = 8 if _TINY else 16
PREFIX_SEQS = 2 if _TINY else 8
PREFIX_LEN = 16 if _TINY else 64
PREFILL_PROMPT = 32 if _TINY else 128
SPEC_GAMMA = 4
SPEC_PATTERN = 6 if _TINY else 8        # repeated n-gram length
SPEC_REPEATS = 4 if _TINY else 8
SPEC_NEW = 16 if _TINY else 48          # tokens generated per engine

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_decode.json")


def _cfg():
    from repro.configs import get_config
    if _TINY:
        return get_config("qwen2-0.5b", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=512, max_seq_len=MAX_LEN)
    return get_config("qwen2-0.5b", n_layers=4, d_model=256, n_heads=4,
                      n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8192,
                      max_seq_len=MAX_LEN)


def modeled_bytes_step(cfg, batch: int, kind: str, *, mean_len: float,
                       page_size: int = PAGE_SIZE) -> float:
    """v5e HBM cache traffic for ONE ragged decode step (all layers, k+v)."""
    kv, hd, nl = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    per_tok = kv * hd                                 # elements per (k or v)
    if kind == "dense-bf16":
        read = batch * nl * 2 * per_tok * MAX_LEN * 2
        write = batch * nl * 2 * per_tok * 2          # append one token
    elif kind == "dense-int8":
        scales = batch * nl * 2 * kv * (MAX_LEN // page_size) * 4
        read = batch * nl * 2 * per_tok * MAX_LEN * 1 + scales
        # append requantizes the touched page in place
        write = batch * nl * 2 * (per_tok * page_size * 1 + kv * 4)
    elif kind == "paged-int8":
        pages = mean_len / page_size + 0.5            # half-empty last page
        page_by = page_size * (per_tok * 1 + kv * 4)  # int8 + per-token scale
        read = batch * nl * 2 * page_by * pages
        read += batch * nl * np.ceil(mean_len / page_size) * 4  # block table
        # write-once append: one token row + its scale, no page requantize
        write = batch * nl * 2 * (per_tok * 1 + kv * 4)
    else:
        raise ValueError(kind)
    return float(read + write)


def _measure_tok_s(cfg, params, batch: int, kind: str) -> float:
    from repro.serving.engine import _generate_dense, generate
    prompt = jax.random.randint(jax.random.PRNGKey(batch), (batch, PROMPT),
                                0, cfg.vocab_size)
    if kind == "paged-int8":
        call = lambda: generate(params, cfg, prompt, steps=STEPS,  # noqa: E731
                                kv_dtype="int8", page_size=PAGE_SIZE)
    else:
        kv_dtype = "int8" if kind == "dense-int8" else None
        call = lambda: _generate_dense(  # noqa: E731
            params, cfg, prompt, steps=STEPS, key=None, sample="greedy",
            temperature=1.0, max_len=MAX_LEN, kv_dtype=kv_dtype)
    jax.block_until_ready(call())          # warm (compile/trace)
    t0 = time.perf_counter()
    toks = call()
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    return batch * STEPS / dt


def _prefix_sharing_entry(cfg, params):
    """N same-prefix requests through the engine: physical pages vs naive."""
    from repro.serving.engine import ContinuousBatchingEngine
    key = jax.random.PRNGKey(7)
    prefix = jax.random.randint(key, (PREFIX_LEN,), 0, cfg.vocab_size)
    tail = PAGE_SIZE
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (tail,), 0,
                                  cfg.vocab_size) for i in range(PREFIX_SEQS)]
    import jax.numpy as jnp
    # admission staggers one prefill per step: budget enough decode tokens
    # that every sequence is still resident when the last one is admitted
    max_new = PREFIX_SEQS + 2
    eng = ContinuousBatchingEngine(
        params, cfg, kv_dtype="int8", page_size=PAGE_SIZE,
        capacity_tokens=PREFIX_SEQS * 2 * (PREFIX_LEN + tail + max_new))
    for p in prompts:
        eng.submit(jnp.concatenate([prefix, p]), max_new)
    while eng.waiting or eng.prefilling:   # drive until every prompt resides
        eng.step()
    stats = eng.pool.shared_page_stats()
    prefix_pages = PREFIX_LEN // PAGE_SIZE
    naive = PREFIX_SEQS * prefix_pages
    entry = {
        "n_seqs": PREFIX_SEQS, "prefix_tokens": PREFIX_LEN,
        "page_size": PAGE_SIZE,
        "shared_prefix_pages": stats["shared_slots"],
        "naive_prefix_pages": naive,
        "pages_saved": stats["table_entries"] - stats["distinct_slots"],
        "prefix_page_ratio": stats["shared_slots"] / naive,
    }
    eng.run()
    return entry


def _chunked_prefill_entry(cfg, params):
    """Engine prefill tokens/s straight into int8 pages (no dense slab)."""
    from repro.serving.engine import ContinuousBatchingEngine
    prompt = jax.random.randint(jax.random.PRNGKey(9), (PREFILL_PROMPT,), 0,
                                cfg.vocab_size)

    def prefill_once():
        eng = ContinuousBatchingEngine(
            params, cfg, kv_dtype="int8", page_size=PAGE_SIZE,
            capacity_tokens=2 * (PREFILL_PROMPT + 1))
        eng.submit(prompt, 1)
        steps = 0
        while eng.waiting or eng.prefilling:
            eng.step()
            steps += 1
        return eng, steps

    # the engine's token sampling host-syncs, so prefill_once returns with
    # all device work drained — no extra barrier needed before reading t1
    eng, _ = prefill_once()                 # warm (compile/trace)
    t0 = time.perf_counter()
    eng, chunks = prefill_once()
    dt = time.perf_counter() - t0
    return {
        "prompt_tokens": PREFILL_PROMPT,
        "chunk_tokens": eng.chunk_tokens,
        "pages_per_step": eng.pages_per_step,
        "chunk_steps": chunks,
        "measured_prefill_tok_s": PREFILL_PROMPT / dt,
    }


def _tensor_parallel_entry(cfg, params, tp: int, mean_len: float):
    """Head-sharded TP serving: per-device cache stream + collective cost."""
    base = modeled_bytes_step(cfg, 8, "paged-int8", mean_len=mean_len)
    sharded = cfg.n_kv_heads % tp == 0
    kv_div = tp if sharded else 1
    # the two row-parallel all-reduces per layer (wo + w_down) move one
    # (batch, d_model) partial each: a ring f32 psum puts 2·(tp-1)/tp of
    # the payload on each device's wire; quantized_psum all-gathers every
    # peer's FULL int8 partial — (tp-1)·payload per device — and sums
    # locally, so the compression is 4x at tp=2 and washes out by tp=8
    payload_f32 = 8 * cfg.d_model * 4
    payload_int8 = 8 * cfg.d_model * 1 + 4
    coll_f32 = (cfg.n_layers * 2 * (2 * (tp - 1) / tp)
                * payload_f32 / 8)                   # per token
    coll_int8 = cfg.n_layers * 2 * (tp - 1) * payload_int8 / 8
    entry = {
        "tp": tp,
        "kv_heads_sharded": sharded,
        "modeled_hbm_bytes_step_per_device": base / kv_div,
        "modeled_collective_bytes_token_f32": coll_f32,
        "modeled_collective_bytes_token_int8": coll_int8,
        "measured_tok_s": None,
    }
    n_dev = len(jax.devices())
    if n_dev >= tp and n_dev % tp == 0:   # make_serving_mesh needs tp | n_dev
        from repro.launch.mesh import make_serving_mesh
        from repro.launch.serve import shard_params
        from repro.serving.engine import generate
        mesh = make_serving_mesh(tp, data=n_dev // tp)
        resident = shard_params(params, mesh)   # weights resident-sharded,
        prompt = jax.random.randint(jax.random.PRNGKey(3), (2, PROMPT), 0,
                                    cfg.vocab_size)
        call = lambda: generate(resident, cfg, prompt, steps=STEPS,  # noqa: E731
                                kv_dtype="int8", page_size=PAGE_SIZE,
                                mesh=mesh)
        jax.block_until_ready(call())      # warm (compile/trace)
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        entry["measured_tok_s"] = 2 * STEPS / (time.perf_counter() - t0)
    else:
        entry["measured_skipped"] = \
            f"host has {n_dev} device(s), not a multiple of tp={tp}"
    return entry


def _speculative_entry(cfg, params):
    """N-gram draft–verify on a repetitive prompt vs the plain engine.

    Repetitive contexts (code, retrieved documents, generation loops) are
    where prompt-lookup drafting shines; tiny greedy models also settle
    into cycles, so the drafter keeps predicting the continuation and the
    verify panel amortizes the weight stream over several emitted tokens.
    """
    import jax.numpy as jnp

    from repro.serving.engine import ContinuousBatchingEngine
    from repro.serving.spec_decode import SpecConfig
    pattern = jax.random.randint(jax.random.PRNGKey(11), (SPEC_PATTERN,), 0,
                                 cfg.vocab_size)
    prompt = jnp.tile(pattern, SPEC_REPEATS)

    def run(spec):
        def once():
            eng = ContinuousBatchingEngine(
                params, cfg, kv_dtype="int8", page_size=PAGE_SIZE,
                capacity_tokens=4 * (int(prompt.shape[0]) + SPEC_NEW),
                spec=spec)
            sid = eng.submit(prompt, SPEC_NEW)
            return eng.run()[sid], eng

        once()                             # warm (compile every panel width)
        t0 = time.perf_counter()
        toks, eng = once()
        return toks, time.perf_counter() - t0, eng

    base_toks, base_dt, _ = run(None)
    spec = SpecConfig(method="ngram", gamma=SPEC_GAMMA)
    spec_toks, spec_dt, eng = run(spec)
    s = eng.spec_summary()
    # weight-stream roofline: one verify forward streams the weights once
    # for mean_tokens_per_step emitted tokens
    tps = max(s["mean_tokens_per_step"], 1.0)
    return {
        "method": "ngram", "gamma": SPEC_GAMMA,
        "prompt_tokens": int(prompt.shape[0]), "new_tokens": SPEC_NEW,
        "spec_steps": s["spec_steps"], "proposed": s["proposed"],
        "accepted": s["accepted"],
        "acceptance_rate": s["acceptance_rate"],
        "mean_tokens_per_step": s["mean_tokens_per_step"],
        "greedy_parity": bool(base_toks == spec_toks),
        "measured_baseline_tok_s": SPEC_NEW / base_dt,
        "measured_spec_tok_s": SPEC_NEW / spec_dt,
        "modeled_weight_stream_ratio": 1.0 / tps,
    }


def rows(mesh_tp: int = _MESH_TP, spec: bool = _SPEC):
    from repro.models import init_params
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mean_len = PROMPT + STEPS / 2
    report = {"bench": "decode_serving", "prompt": PROMPT, "steps": STEPS,
              "max_len": MAX_LEN, "page_size": PAGE_SIZE, "batches": []}
    for batch in BATCHES:
        entry = {"batch": batch, "kinds": {}}
        base = modeled_bytes_step(cfg, batch, "dense-bf16", mean_len=mean_len)
        for kind in ("dense-bf16", "dense-int8", "paged-int8"):
            by = modeled_bytes_step(cfg, batch, kind, mean_len=mean_len)
            tok_s = _measure_tok_s(cfg, params, batch, kind)
            entry["kinds"][kind] = {
                "measured_tok_s": tok_s,
                "modeled_hbm_bytes_step": by,
                "ratio_vs_dense_bf16": by / base,
            }
            yield csv_row(
                f"decode_serving/b{batch}/{kind}", 1e6 / tok_s,
                f"{tok_s:.1f} tok/s; modeled {by / 1e6:.3f} MB/step "
                f"(x{by / base:.3f} of dense-bf16)")
        report["batches"].append(entry)
    b8 = next((e for e in report["batches"] if e["batch"] == 8),
              report["batches"][-1])
    ratio = b8["kinds"]["paged-int8"]["ratio_vs_dense_bf16"]
    report["paged_int8_vs_dense_bf16_at_b8"] = ratio

    share = _prefix_sharing_entry(cfg, params)
    report["prefix_sharing"] = share
    yield csv_row(
        "decode_serving/prefix_sharing", 0.0,
        f"{share['n_seqs']} seqs x {share['prefix_tokens']}-tok prefix: "
        f"{share['shared_prefix_pages']} shared pages vs "
        f"{share['naive_prefix_pages']} naive "
        f"({share['pages_saved']} saved)")

    pre = _chunked_prefill_entry(cfg, params)
    report["chunked_prefill"] = pre
    yield csv_row(
        "decode_serving/chunked_prefill", 1e6 / pre["measured_prefill_tok_s"],
        f"{pre['measured_prefill_tok_s']:.1f} prefill tok/s; "
        f"chunk {pre['chunk_tokens']} tok, "
        f"{pre['pages_per_step']} pages/grid-step, no dense KV slab")

    if spec:
        se = _speculative_entry(cfg, params)
        report["speculative"] = se
        yield csv_row(
            "decode_serving/speculative", 1e6 / se["measured_spec_tok_s"],
            f"ngram gamma={se['gamma']}: acceptance "
            f"{se['acceptance_rate']:.2f}, "
            f"{se['mean_tokens_per_step']:.2f} tok/verify-step "
            f"(weight stream x{se['modeled_weight_stream_ratio']:.2f}); "
            f"greedy parity {se['greedy_parity']}")

    if mesh_tp > 1:
        tpe = _tensor_parallel_entry(cfg, params, mesh_tp, mean_len)
        report["tensor_parallel"] = tpe
        meas = (f"{tpe['measured_tok_s']:.1f} tok/s"
                if tpe["measured_tok_s"] else "modeled only")
        yield csv_row(
            f"decode_serving/tensor_parallel/tp{mesh_tp}",
            0.0 if not tpe["measured_tok_s"] else 1e6 / tpe["measured_tok_s"],
            f"{meas}; {tpe['modeled_hbm_bytes_step_per_device'] / 1e6:.3f} "
            f"MB/step/device; collectives "
            f"{tpe['modeled_collective_bytes_token_f32'] / 1e3:.2f} kB/tok "
            f"f32 -> {tpe['modeled_collective_bytes_token_int8'] / 1e3:.2f} "
            f"kB/tok int8 wire")

    yield f"# paged-int8 / dense-bf16 modeled bytes at b8: {ratio:.3f}"
    if _TINY:
        yield "# tiny smoke mode: skipping BENCH_decode.json write"
        return
    with open(_JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    yield f"# wrote {os.path.normpath(_JSON_PATH)}"


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, default=_MESH_TP, metavar="TP",
                    help="model-axis degree for the tensor_parallel section "
                         "(0 = off; measured when the host has >= TP devices)")
    ap.add_argument("--spec", action=argparse.BooleanOptionalAction,
                    default=_SPEC,
                    help="emit the speculative-decoding section (n-gram "
                         "draft-verify on a repetitive-prompt workload); "
                         "on by default (REPRO_BENCH_SPEC=0 or --no-spec "
                         "disables)")
    args = ap.parse_args()
    for row in rows(mesh_tp=args.mesh, spec=args.spec):
        print(row)
