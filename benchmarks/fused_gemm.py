"""Fused vs unfused CAMP GEMM: measured wall-clock + modeled HBM traffic.

Two numbers per shape, following the repo convention (benchmarks/common.py):

* measured — wall-clock of ``camp_matmul`` on THIS host's backend. On the
  CPU container both paths lower through XLA (``impl='xla'``): the unfused
  path is the historical quantize→GEMM→epilogue composition of separate
  dispatches, the fused path is the single jitted graph the fused kernels
  correspond to. On TPU the same entry points hit the Pallas kernels.
* modeled — v5e HBM bytes and roofline time for bf16-activation serving.
  Fusing removes the activation-side int8 round-trip (write int8 + scales,
  re-read int8) that the unfused path pays between the two kernels.

Also emits ``BENCH_fused_gemm.json`` at the repo root so the perf trajectory
of this optimization is recorded run over run.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, PEAK_INT8, csv_row, time_call

# (M, N, K, qmode, tag, reps) — decode- and prefill-shaped LLM linears. The
# small decode shape is where fusion shows up in *measured* host time even on
# CPU (the GEMM is cheap, the extra quantize dispatch + int8 round-trip is
# not); the large shapes are there for the modeled-bytes trajectory.
SHAPES = [
    (4, 1024, 1024, "w8a8", "decode-b4", 30),
    (16, 4096, 4096, "w8a8", "decode-b16", 5),
    (16, 4096, 4096, "w4a8", "decode-b16-w4", 5),
    (256, 2048, 2048, "w8a8", "prefill-256", 5),
]

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_fused_gemm.json")


def modeled_hbm_bytes(m: int, n: int, k: int, qmode: str, fused: bool,
                      a_in_bytes: int = 2) -> float:
    """Activation + weight + output HBM traffic for one GEMM (bf16 acts)."""
    a_bits = 4 if qmode == "w4a4" else 8
    w_bytes = k * n * (0.5 if qmode.startswith("w4") else 1.0)
    act = m * k * a_in_bytes                 # read activations once
    if not fused:
        # quantize kernel writes int8(+scales), GEMM re-reads them from HBM
        act += 2 * m * k * (a_bits / 8) + 4 * m
    return act + w_bytes + m * n * 2 + 4 * (m + n)


def modeled_time_s(m, n, k, qmode, fused) -> float:
    flops = 2.0 * m * n * k
    rate = 2 * PEAK_INT8 if qmode == "w4a4" else PEAK_INT8
    return max(flops / rate, modeled_hbm_bytes(m, n, k, qmode, fused) / HBM_BW)


def rows():
    from repro.core import camp
    rng = np.random.default_rng(0)
    report = {"bench": "fused_gemm", "impl": "xla", "shapes": []}
    for m, n, k, qmode, tag, reps in SHAPES:
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        wq = camp.prepare_weight(w, qmode)

        def unfused(x=x, wq=wq, qmode=qmode):
            return camp.camp_matmul(x, wq, qmode=qmode, impl="xla",
                                    fused=False)

        def fused(x=x, wq=wq, qmode=qmode):
            return camp.camp_matmul(x, wq, qmode=qmode, impl="xla", fused=True)

        t_un = time_call(unfused, reps=reps)
        t_fu = time_call(fused, reps=reps)
        by_un = modeled_hbm_bytes(m, n, k, qmode, fused=False)
        by_fu = modeled_hbm_bytes(m, n, k, qmode, fused=True)
        entry = {
            "tag": tag, "m": m, "n": n, "k": k, "qmode": qmode,
            "measured_unfused_us": t_un * 1e6,
            "measured_fused_us": t_fu * 1e6,
            "measured_speedup": t_un / t_fu,
            "modeled_hbm_bytes_unfused": by_un,
            "modeled_hbm_bytes_fused": by_fu,
            "modeled_hbm_bytes_saved": by_un - by_fu,
            "modeled_v5e_us_unfused": modeled_time_s(m, n, k, qmode, False) * 1e6,
            "modeled_v5e_us_fused": modeled_time_s(m, n, k, qmode, True) * 1e6,
        }
        report["shapes"].append(entry)
        yield csv_row(
            f"fused_gemm/{tag}/unfused", t_un * 1e6,
            f"modeled {by_un / 1e6:.2f} MB")
        yield csv_row(
            f"fused_gemm/{tag}/fused", t_fu * 1e6,
            f"modeled {by_fu / 1e6:.2f} MB; speedup x{t_un / t_fu:.2f}")
    with open(_JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)
    yield f"# wrote {os.path.normpath(_JSON_PATH)}"
