"""Paper Fig 17 analogue: instruction-count reduction.

The paper counts vector ISA instructions (CAMP's single outer-product
instruction replaces broadcast+MAC chains). The XLA analogue is the optimized
HLO op count of one fused CAMP GEMM versus the *unfused* chain (separate
quantize / int-matmul / scale-dequant programs, as a naive library would
dispatch them), plus the Pallas kernel which is literally ONE fused kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.quant import quantize_weight
from repro.kernels import ops, ref

M, K, N = 512, 512, 512


def _n_ops(compiled) -> int:
    txt = compiled.as_text()
    return sum(1 for line in txt.splitlines()
               if "=" in line and not line.strip().startswith(("//", "HloModule",
                                                               "ENTRY", "}")))


def rows():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
    wq = quantize_weight(w, 8)

    # unfused chain: 3 separately-dispatched programs (library style)
    c_quant = jax.jit(lambda a: ops.quantize_rowwise(a, impl="ref")).lower(x).compile()
    a_q, a_s = ops.quantize_rowwise(x, impl="ref")
    c_mm = jax.jit(lambda q, b: ref.dot_i32(q, b)).lower(a_q, wq.q).compile()
    acc = ref.dot_i32(a_q, wq.q)
    c_deq = jax.jit(
        lambda i32, sa, sb: (i32.astype(jnp.float32) * (sa * sb))
    ).lower(acc, a_s, wq.scale).compile()
    unfused = _n_ops(c_quant) + _n_ops(c_mm) + _n_ops(c_deq)

    # fused CAMP op: one program
    from repro.core import camp
    c_fused = jax.jit(
        lambda a: camp.camp_matmul(a, wq, qmode="w8a8", impl="xla")
    ).lower(x).compile()
    fused = _n_ops(c_fused)

    out = [
        csv_row("fig17_hlo_ops_unfused_chain", 0.0, f"ops={unfused}"),
        csv_row("fig17_hlo_ops_camp_fused", 0.0,
                f"ops={fused};reduction={unfused / max(fused, 1):.2f}x"),
        csv_row("fig17_pallas_kernel_launches", 0.0,
                "camp_gemm=1_fused_kernel (quantize+matmul+scale epilogue)"),
        csv_row("fig17_paper_claim", 0.0,
                "total_instr_reduction~2x;vector_instr_reduction>8x"),
    ]
    return out


if __name__ == "__main__":
    print("\n".join(rows()))
