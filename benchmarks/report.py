"""Regenerate the §Roofline table and §Perf hillclimb table inside
EXPERIMENTS.md from the dry-run artifacts.

    PYTHONPATH=src:. python -m benchmarks.report
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from benchmarks.roofline import load, table

ROOT = Path(__file__).resolve().parent.parent
EXP = ROOT / "EXPERIMENTS.md"
ART = ROOT / "artifacts" / "dryrun"


def _analytic_decode_bytes(r):
    """Per-device analytic HBM bytes for one decode step: resident weights
    read once + KV cache read once (the true memory-term floor; the HLO
    'bytes accessed' from the CPU backend includes fusion artifacts)."""
    from repro.configs import get_config
    try:
        cfg = get_config(r["arch"])
    except KeyError:
        return None
    if r["kind"] != "decode":
        return None
    wb = {"none": 2.0, "w8a8": 1.0, "w8a16": 1.0, "w4a8": 0.5,
          "w4a16": 0.5, "w4a4": 0.5}[r["qmode"]]
    n_dev = r["n_devices"]
    weights = cfg.param_count() * wb / n_dev          # every param read once
    kv_b = 1 if r.get("kv_dtype") == "int8" else 2
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.mixer_of(i) == "attn")
    seq = {"decode_32k": 32768, "long_500k": 524288}.get(r["shape"], 0)
    batch = {"decode_32k": 128, "long_500k": 1}.get(r["shape"], 0)
    kv = (2 * n_attn * batch * cfg.n_kv_heads * seq * cfg.hd * kv_b) / n_dev
    return weights + kv


def _fmt(r):
    rf, m = r["roofline"], r["memory"]
    ana = _analytic_decode_bytes(r)
    ana_s = f"{ana / 819e9 * 1e3:.2f}" if ana else "—"
    return (f"| {r.get('tag', '')} | {r['qmode']}"
            f"{'+kv8' if r.get('kv_dtype') else ''} "
            f"| {rf['compute_s'] * 1e3:.2f} | {rf['memory_s'] * 1e3:.2f} "
            f"| {ana_s} "
            f"| {rf['collective_s'] * 1e3:.2f} | {rf['bottleneck'].replace('_s', '')} "
            f"| {rf['roofline_frac']:.4f} | {m['peak_bytes'] / 2**30:.1f} |")


def hillclimb_tables():
    cells = {
        "A — qwen2-72b × decode_32k (paper-representative, memory-bound)":
            "qwen2-72b__decode_32k__single__*",
        "B — qwen2-72b × train_4k (most collective-bound; ladder climbed on "
        "2–4L probes, see prose above — full-scale baseline row)":
            "qwen2-72b__train_4k__single__*",
        "C — pixtral-12b × prefill_32k (worst roofline fraction)":
            "pixtral-12b__prefill_32k__single__*",
        "bonus — jamba-v0.1-52b × decode_32k quantization ladder":
            "jamba-v0.1-52b__decode_32k__single__*",
        "bonus — llama4-maverick-400b × decode_32k (EP serving; only fits "
        "quantized)":
            "llama4-maverick-400b-a17b__decode_32k__single__*",
    }
    out = []
    for title, pat in cells.items():
        recs = []
        for p in sorted(ART.glob(f"{pat}.json")):
            r = json.loads(p.read_text())
            if r.get("status") == "OK":
                recs.append(r)
        if not recs:
            continue
        out.append(f"**{title}**\n")
        out.append("| variant | qmode | compute ms | memory ms (HLO) "
                   "| memory ms (analytic) | collective ms "
                   "| bound | frac | peak GiB |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        recs.sort(key=lambda r: r.get("tag", ""))
        for r in recs:
            out.append(_fmt(r))
        out.append("")
    return "\n".join(out)


def main():
    txt = EXP.read_text()
    roof = "```\n" + "\n".join(table("single")) + "\n```"
    txt = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## )",
                 f"<!-- ROOFLINE_TABLE -->\n{roof}\n\n", txt, flags=re.S) \
        if "<!-- ROOFLINE_TABLE -->" in txt else txt
    analysis = _analysis_block()
    txt = re.sub(r"<!-- ROOFLINE_ANALYSIS -->.*?(?=\n## )",
                 f"<!-- ROOFLINE_ANALYSIS -->\n{analysis}\n\n", txt,
                 flags=re.S) if "<!-- ROOFLINE_ANALYSIS -->" in txt else txt
    hc = hillclimb_tables()
    txt = re.sub(r"<!-- PERF_HILLCLIMB -->.*?(?=\n## |\Z)",
                 f"<!-- PERF_HILLCLIMB -->\n\n{hc}\n", txt, flags=re.S) \
        if "<!-- PERF_HILLCLIMB -->" in txt else txt
    EXP.write_text(txt)
    print("EXPERIMENTS.md refreshed "
          f"({len(load('single'))} single-pod cells, hillclimb rows embedded)")


def _analysis_block():
    recs = [r for r in load("single") if r.get("status") == "OK"
            and not r.get("tag")]
    if not recs:
        return "(awaiting sweep)"
    bounds = {}
    for r in recs:
        bounds.setdefault(r["roofline"]["bottleneck"], []).append(
            f"{r['arch']}×{r['shape']}")
    lines = ["Dominant-term census (baseline cells):", ""]
    for b, cells in sorted(bounds.items()):
        lines.append(f"* **{b.replace('_s', '')}-bound** ({len(cells)}): "
                     + ", ".join(cells))
    lines.append("")
    lines.append("Per-cell one-line 'what moves the dominant term':")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        b = r["roofline"]["bottleneck"]
        hint = {
            "memory_s": "cut bytes: lower-bit storage (w4a8/int8-KV) or "
                        "fuse score traffic (flash kernel)",
            "compute_s": "raise useful-FLOPs ratio: less remat recompute, "
                         "int8 MXU rate for GEMMs",
            "collective_s": "reshape collectives: larger MoE groups / "
                            "resident weights / overlapped ring matmul",
        }[b]
        lines.append(f"* {r['arch']} × {r['shape']} [{r['qmode']}]: "
                     f"{b.replace('_s', '')}-bound → {hint}")
    return "\n".join(lines)


if __name__ == "__main__":
    main()
