"""§Roofline reporting: reads the dry-run artifacts and prints the
per-(arch × shape) three-term roofline table used in EXPERIMENTS.md."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_row

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load(mesh: str = "single"):
    recs = []
    if not ART.exists():
        return recs
    for p in sorted(ART.glob(f"*__{mesh}__*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def table(mesh: str = "single"):
    lines = []
    hdr = (f"{'arch':<26} {'shape':<12} {'q':<5} {'stat':<4} "
           f"{'compute_ms':>10} {'memory_ms':>10} {'coll_ms':>9} "
           f"{'bound':<12} {'useful':>6} {'frac':>6} {'peakGiB':>8} fit")
    lines.append(hdr)
    for r in load(mesh):
        if r["status"].startswith("SKIP"):
            lines.append(f"{r['arch']:<26} {r['shape']:<12} {r['qmode']:<5} SKIP"
                         f"  (sub-quadratic-only shape on attention arch)")
            continue
        if r["status"] == "FAIL":
            lines.append(f"{r['arch']:<26} {r['shape']:<12} {r['qmode']:<5} FAIL"
                         f"  {r.get('error', '')[:70]}")
            continue
        rf = r["roofline"]
        m = r["memory"]
        tag = r.get("tag", "")
        lines.append(
            f"{r['arch']:<26} {r['shape']:<12} {r['qmode']:<5} OK  "
            f"{rf['compute_s'] * 1e3:>10.2f} {rf['memory_s'] * 1e3:>10.2f} "
            f"{rf['collective_s'] * 1e3:>9.2f} "
            f"{rf['bottleneck'].replace('_s', ''):<12} "
            f"{rf['useful_flops_ratio']:>6.2f} {rf['roofline_frac']:>6.3f} "
            f"{m['peak_bytes'] / 2**30:>8.2f} {'Y' if m['fits_16g'] else 'N'}"
            + (f"  [{tag}]" if tag else ""))
    return lines


def rows():
    out = []
    for r in load("single"):
        if r["status"] != "OK":
            out.append(csv_row(
                f"roofline_{r['arch']}_{r['shape']}_{r['qmode']}", 0.0,
                r["status"]))
            continue
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        out.append(csv_row(
            f"roofline_{r['arch']}_{r['shape']}_{r['qmode']}",
            dom * 1e6,
            f"bound={rf['bottleneck']};frac={rf['roofline_frac']:.3f};"
            f"useful={rf['useful_flops_ratio']:.2f};"
            f"fits16G={r['memory']['fits_16g']}"))
    return out


if __name__ == "__main__":
    print("\n".join(table("single")))
