"""Benchmark harness entrypoint — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (blocking_locality, cnn_llm_layers, decode_serving,
                            fused_gemm, instruction_count, roofline,
                            table1_smm, table4_conv)
    sections = [
        ("Table 1 (SMM 512 speedups)", table1_smm.rows),
        ("Fig 1 (blocking locality)", blocking_locality.rows),
        ("Figs 12/13/14 + Table 3 (CNN/LLM layers)", cnn_llm_layers.rows),
        ("Table 4 (conv throughput)", table4_conv.rows),
        ("Fig 17 (instruction count)", instruction_count.rows),
        ("Roofline (dry-run artifacts)", roofline.rows),
        ("Fused quantize+GEMM (ISSUE 1)", fused_gemm.rows),
        ("Paged-KV decode serving (ISSUE 2)", decode_serving.rows),
    ]
    print("name,us_per_call,derived")
    ok = True
    for title, fn in sections:
        print(f"# --- {title} ---")
        try:
            for row in fn():
                print(row)
        except Exception:  # noqa: BLE001
            ok = False
            print(f"# SECTION FAILED: {title}")
            traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
