"""Paper Table 1: int8/int4 speedup over FP32 for 512×512 square matmul.

Paper's claims (their hardware):
  ARMv8+SVE/CAMP : int8 7.4×, int4 12.4×
  RISC-V/CAMP    : int8 14.1×, int4 25.1×

Here: v5e-modeled CAMP speedups + measured XLA-CPU wall-times of the actual
jitted CAMP ops (correctness-carrying path, not a TPU proxy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, modeled_gemm_s, time_call
from repro.core import camp
from repro.kernels import ops

N = 512


def rows():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((N, N)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((N, N)).astype(np.float32))

    fp32 = jax.jit(lambda a, b: a @ b)
    t_fp32 = time_call(fp32, x, w)

    out = []
    t_mode = {}
    for mode in ("w8a8", "w4a8", "w4a4"):
        wq = camp.prepare_weight(w, mode)
        f = jax.jit(lambda a, wq=wq, m=mode: camp.camp_matmul(a, wq, qmode=m,
                                                              impl="xla"))
        t = time_call(f, x)
        t_mode[mode] = t
        model_speedup = modeled_gemm_s(N, N, N, "fp32") / modeled_gemm_s(N, N, N, mode)
        out.append(csv_row(
            f"table1_smm512_{mode}", t * 1e6,
            f"modeled_v5e_speedup_vs_fp32={model_speedup:.1f}x;"
            f"measured_cpu_speedup={t_fp32 / t:.2f}x"))
    out.append(csv_row("table1_smm512_fp32", t_fp32 * 1e6, "baseline=1x"))
    # paper reference points for the reader
    out.append(csv_row("table1_paper_claim_int8", 0.0,
                       "ARM/CAMP=7.4x;RISCV/CAMP=14.1x"))
    out.append(csv_row("table1_paper_claim_int4", 0.0,
                       "ARM/CAMP=12.4x;RISCV/CAMP=25.1x"))
    return out


if __name__ == "__main__":
    print("\n".join(rows()))
