"""Paper Table 4: throughput/efficiency on the reference convolution.

Benchmark from the paper: input H×W×F = 16×16×32, filters 64×3×3×32
→ im2col GEMM (M=196, K=288, N=64). The paper reports 12.6–21.7 GOPS on a
1 GHz edge RISC-V (this work) vs 0.2–47.9 GOPS for prior SIMD designs.

Here: modeled v5e GOPS for the same GEMM under each CAMP mode (per chip),
plus measured XLA-CPU GOPS of the real op.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, modeled_gemm_s, time_call
from repro.core import camp

M, K, N = 14 * 14, 3 * 3 * 32, 64  # im2col of the paper's conv


def rows():
    gops_needed = 2 * M * K * N / 1e9
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))
    out = []
    for mode in ("w8a8", "w4a4"):
        wq = camp.prepare_weight(w, mode)
        f = jax.jit(lambda a, m=mode, q=wq: camp.camp_matmul(a, q, qmode=m,
                                                             impl="xla"))
        t = time_call(f, x)
        modeled = gops_needed / modeled_gemm_s(M, N, K, mode)
        out.append(csv_row(
            f"table4_conv_{mode}", t * 1e6,
            f"measured_cpu_gops={gops_needed / t:.2f};"
            f"modeled_v5e_gops={modeled:.0f}"))
    out.append(csv_row("table4_paper_claim", 0.0,
                       "this_work=12.6-21.7GOPS@1GHz_RISCV;"
                       "prior_simd=0.2-47.9GOPS"))
    return out


if __name__ == "__main__":
    print("\n".join(rows()))
