"""Fault-tolerance demo: kill the training mid-run, restart, verify the
resumed trajectory matches an uninterrupted one exactly.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import shutil

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.optim import adamw
from repro.train import build_train_step, init_train_state
from repro.train import loop as loop_lib

CKPT = "/tmp/camp_ft_demo"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_config("qwen3-0.6b", reduced=True)
opt = adamw(lr=3e-3)
step = build_train_step(cfg, opt)
data = SyntheticLMData(cfg.vocab_size, 8, 32, seed=0)

# 1) uninterrupted run to step 30
s = init_train_state(jax.random.PRNGKey(0), cfg, opt)
full, hist_full = loop_lib.run(step, s, data, steps=30, log_every=0)

# 2) run to 20 with checkpoints every 10, "crash", restart → 30
s = init_train_state(jax.random.PRNGKey(0), cfg, opt)
s, _ = loop_lib.run(step, s, data, steps=20, ckpt_dir=CKPT, ckpt_every=10,
                    log_every=0)
print("-- simulated crash; restarting from latest checkpoint --")
s2 = init_train_state(jax.random.PRNGKey(0), cfg, opt)   # fresh process state
s2, hist2 = loop_lib.run(step, s2, data, steps=30, ckpt_dir=CKPT,
                         ckpt_every=10, log_every=0)

a = np.asarray(full["params"]["final_norm"], np.float32)
b = np.asarray(s2["params"]["final_norm"], np.float32)
print(f"resumed == uninterrupted: {np.allclose(a, b, rtol=1e-5)}")
print(f"final losses: full={hist_full['loss'][-1]:.4f} "
      f"resumed={hist2['loss'][-1]:.4f}")
