"""Quickstart: the CAMP quantized GEMM as a drop-in op.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import camp, quantize_rowwise
from repro.kernels import ops

rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((256, 1024)).astype(np.float32))
w = jnp.asarray(rng.standard_normal((1024, 512)).astype(np.float32))

print("== CAMP quickstart ==")
exact = x @ w

for qmode in ("w8a8", "w4a8", "w4a4"):
    wq = camp.prepare_weight(w, qmode)                 # PTQ: pack + scales
    y = camp.camp_matmul(x, wq, qmode=qmode)           # dynamic act-quant GEMM
    rel = float(jnp.abs(y - exact).max() / jnp.abs(exact).max())
    print(f"{qmode}: weight bytes {wq.memory_bytes():>8} "
          f"(fp32 {w.size * 4}), max rel err {rel:.4f}")

# The Pallas TPU kernel (validated in interpret mode on CPU):
a_q, a_s = quantize_rowwise(x)
wq8 = camp.prepare_weight(w, "w8a8")
y_pallas = ops.gemm_i8(a_q, wq8.q, a_s, wq8.scale, impl="pallas",
                       block=(128, 128, 256))
y_xla = ops.gemm_i8(a_q, wq8.q, a_s, wq8.scale, impl="xla")
print("pallas kernel == xla path:",
      bool(jnp.allclose(y_pallas, y_xla, rtol=2e-6, atol=1e-5)))

# The paper's §3 hybrid multiplier identity (int8 GEMM from 4-bit blocks):
from repro.core.hybrid import hybrid_matmul_i8
from repro.kernels.ref import dot_i32
a8 = jnp.asarray(rng.integers(-128, 128, (64, 64)).astype(np.int8))
b8 = jnp.asarray(rng.integers(-128, 128, (64, 64)).astype(np.int8))
print("hybrid(4-bit blocks) == int8 MXU dot:",
      bool((hybrid_matmul_i8(a8, b8) == dot_i32(a8, b8)).all()))
