"""Serve a small model through the CAMP paged serving stack: PTQ weights →
continuous batching over a shared int8 KV page pool, chunked paged prefill,
copy-on-write prefix sharing.

Eight requests with mixed prompt lengths and token budgets are queued
against a pool deliberately too small to hold them all at once — the engine
admits what fits, prefills chunk by chunk straight into int8 pages (no
dense staging slab), finishes short requests mid-flight, reclaims their
pages, and admits the rest. Three of the prompts share a 32-token prefix,
so after the first of them prefills, the others share its physical pages
through the pool's prefix trie. Compares bf16 vs w8a8 vs w4a8 weights on
top of the same paged int8 cache.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.quant import QuantizedTensor
from repro.models import init_params, quantize_params
from repro.serving.engine import ContinuousBatchingEngine

cfg = get_config("qwen2-0.5b", n_layers=4, d_model=256, n_heads=4,
                 n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8192,
                 max_seq_len=512)
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)

# (prompt_len, max_new_tokens) — deliberately ragged
REQUESTS = [(48, 24), (16, 8), (96, 12), (8, 32),
            (64, 16), (24, 24), (40, 8), (12, 16)]
PAGE_SIZE = 16
CAPACITY_TOKENS = 384   # < sum of worst cases → admission is staggered
SHARED_PREFIX = 32      # first three prompts open with the same 32 tokens

prefix = jax.random.randint(jax.random.fold_in(key, 99), (SHARED_PREFIX,), 0,
                            cfg.vocab_size)
prompts = [jax.random.randint(jax.random.fold_in(key, i), (n,), 0,
                              cfg.vocab_size)
           for i, (n, _) in enumerate(REQUESTS)]
SHARERS = (0, 2, 4)     # the three long prompts carry the shared prefix
prompts = [jnp.concatenate([prefix, p[SHARED_PREFIX:]]) if i in SHARERS else p
           for i, p in enumerate(prompts)]


def weight_bytes(p):
    total = 0
    for leaf in jax.tree.leaves(
            p, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total += leaf.memory_bytes()
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


for qmode in ("none", "w8a8", "w4a8"):
    p = params if qmode == "none" else quantize_params(params, cfg, qmode)
    eng = ContinuousBatchingEngine(p, cfg, kv_dtype="int8",
                                  page_size=PAGE_SIZE,
                                  capacity_tokens=CAPACITY_TOKENS)
    sids = [eng.submit(prompts[i], mx) for i, (_, mx) in enumerate(REQUESTS)]
    t0 = time.time()
    steps = peak_saved = 0
    while eng.step():
        steps += 1
        stats = eng.pool.shared_page_stats()
        peak_saved = max(peak_saved,
                         stats["table_entries"] - stats["distinct_slots"])
    dt = time.time() - t0
    outs = {sid: r.tokens for sid, r in eng.finished.items()}
    n_new = sum(len(t) for t in outs.values())
    pool_mib = eng.pool.num_pages * eng.pool.page_bytes() / 2**20
    print(f"{qmode:>5}: weights {weight_bytes(p) / 2**20:6.1f} MiB | "
          f"{n_new} toks over {steps} ragged steps | "
          f"{n_new / dt:6.1f} tok/s (incl. compile) | "
          f"pool {eng.pool.num_pages} pages = {pool_mib:.2f} MiB, "
          f"{eng.pool.num_free} free at end, "
          f"peak {peak_saved} pages saved by prefix sharing")
    first = outs[sids[0]]
    print(f"       first request: {np.asarray(first[:8]).tolist()}")
