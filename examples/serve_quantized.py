"""Serve a small model through the CAMP paged serving stack: PTQ weights →
continuous batching over a shared int8 KV page pool, chunked paged prefill,
copy-on-write prefix sharing, and draft–verify speculative decoding.

Eight requests with mixed prompt lengths and token budgets are queued
against a pool deliberately too small to hold them all at once — the engine
admits what fits, prefills chunk by chunk straight into int8 pages (no
dense staging slab), finishes short requests mid-flight, reclaims their
pages, and admits the rest. Three of the prompts share a 32-token prefix,
so after the first of them prefills, the others share its physical pages
through the pool's prefix trie. Compares bf16 vs w8a8 vs w4a8 weights on
top of the same paged int8 cache.

The speculative section then re-serves a repetitive prompt with
``--spec-method ngram`` (default): the prompt-lookup drafter proposes γ
tokens per step, one γ+1-row verify forward scores them over the paged
cache, rejected suffixes roll back token-granularly, and the per-request
acceptance-rate stats are printed — greedy output is bit-identical to the
non-speculative run.

    PYTHONPATH=src python examples/serve_quantized.py
    PYTHONPATH=src python examples/serve_quantized.py --spec-method off
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.quant import QuantizedTensor
from repro.models import init_params, quantize_params
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.spec_decode import SpecConfig

ap = argparse.ArgumentParser()
ap.add_argument("--spec-method", default="ngram",
                choices=["off", "ngram", "draft"])
ap.add_argument("--spec-gamma", type=int, default=4)
ARGS = ap.parse_args()

cfg = get_config("qwen2-0.5b", n_layers=4, d_model=256, n_heads=4,
                 n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8192,
                 max_seq_len=512)
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)

# (prompt_len, max_new_tokens) — deliberately ragged
REQUESTS = [(48, 24), (16, 8), (96, 12), (8, 32),
            (64, 16), (24, 24), (40, 8), (12, 16)]
PAGE_SIZE = 16
CAPACITY_TOKENS = 384   # < sum of worst cases → admission is staggered
SHARED_PREFIX = 32      # first three prompts open with the same 32 tokens

prefix = jax.random.randint(jax.random.fold_in(key, 99), (SHARED_PREFIX,), 0,
                            cfg.vocab_size)
prompts = [jax.random.randint(jax.random.fold_in(key, i), (n,), 0,
                              cfg.vocab_size)
           for i, (n, _) in enumerate(REQUESTS)]
SHARERS = (0, 2, 4)     # the three long prompts carry the shared prefix
prompts = [jnp.concatenate([prefix, p[SHARED_PREFIX:]]) if i in SHARERS else p
           for i, p in enumerate(prompts)]


def weight_bytes(p):
    total = 0
    for leaf in jax.tree.leaves(
            p, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total += leaf.memory_bytes()
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


for qmode in ("none", "w8a8", "w4a8"):
    p = params if qmode == "none" else quantize_params(params, cfg, qmode)
    eng = ContinuousBatchingEngine(p, cfg, kv_dtype="int8",
                                  page_size=PAGE_SIZE,
                                  capacity_tokens=CAPACITY_TOKENS)
    sids = [eng.submit(prompts[i], mx) for i, (_, mx) in enumerate(REQUESTS)]
    t0 = time.time()
    steps = peak_saved = 0
    while eng.step():
        steps += 1
        stats = eng.pool.shared_page_stats()
        peak_saved = max(peak_saved,
                         stats["table_entries"] - stats["distinct_slots"])
    dt = time.time() - t0
    outs = {sid: r.tokens for sid, r in eng.finished.items()}
    n_new = sum(len(t) for t in outs.values())
    pool_mib = eng.pool.num_pages * eng.pool.page_bytes() / 2**20
    print(f"{qmode:>5}: weights {weight_bytes(p) / 2**20:6.1f} MiB | "
          f"{n_new} toks over {steps} ragged steps | "
          f"{n_new / dt:6.1f} tok/s (incl. compile) | "
          f"pool {eng.pool.num_pages} pages = {pool_mib:.2f} MiB, "
          f"{eng.pool.num_free} free at end, "
          f"peak {peak_saved} pages saved by prefix sharing")
    first = outs[sids[0]]
    print(f"       first request: {np.asarray(first[:8]).tolist()}")

# ---------------------------------------------------------------------------
# Speculative decoding: draft–verify over the same paged int8 cache
# ---------------------------------------------------------------------------
if ARGS.spec_method != "off":
    qp = quantize_params(params, cfg, "w8a8")
    pattern = jax.random.randint(jax.random.fold_in(key, 7), (8,), 0,
                                 cfg.vocab_size)
    rep_prompt = jnp.tile(pattern, 8)            # 64 repetitive tokens
    MAX_NEW = 48

    spec = SpecConfig(method=ARGS.spec_method, gamma=ARGS.spec_gamma)
    if ARGS.spec_method == "draft":
        # toy self-draft: in production this is a much smaller checkpoint
        spec.draft_cfg, spec.draft_params = cfg, qp

    streams = {}
    for label, sp in (("baseline", None), ("speculative", spec)):
        eng = ContinuousBatchingEngine(qp, cfg, kv_dtype="int8",
                                       page_size=PAGE_SIZE,
                                       capacity_tokens=512, spec=sp)
        sid = eng.submit(rep_prompt, MAX_NEW)
        t0 = time.time()
        streams[label] = eng.run()[sid]
        dt = time.time() - t0
        line = f"{label:>11}: {MAX_NEW} toks in {dt:5.2f}s"
        if sp is not None:
            s = eng.spec_summary()
            line += (f" | {s['spec_steps']} verify steps, acceptance "
                     f"{s['acceptance_rate']:.2f}, "
                     f"{s['mean_tokens_per_step']:.2f} tok/step "
                     f"(gamma={s['gamma']})")
            per = next(iter(s["per_request"].values()))
            line += (f"\n             per-request: proposed {per['proposed']},"
                     f" accepted {per['accepted']}")
        print(line)
    match = streams["baseline"] == streams["speculative"]
    print(f"             greedy streams bit-identical: {match}")
    assert match, "speculative greedy decode diverged from baseline"
