"""Serve a small model with batched requests through the CAMP pipeline:
PTQ → prefill → batched greedy decode, comparing bf16 vs w8a8 vs w4a8
outputs and weight footprints.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.quant import QuantizedTensor
from repro.models import init_params, quantize_params
from repro.serving.engine import generate

cfg = get_config("qwen2-0.5b", n_layers=4, d_model=256, n_heads=4,
                 n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8192,
                 max_seq_len=512)
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)

B, PROMPT, STEPS = 4, 48, 24
prompt = jax.random.randint(key, (B, PROMPT), 0, cfg.vocab_size)


def weight_bytes(p):
    total = 0
    for leaf in jax.tree.leaves(
            p, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total += leaf.memory_bytes()
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


for qmode in ("none", "w8a8", "w4a8"):
    p = params if qmode == "none" else quantize_params(params, cfg, qmode)
    t0 = time.time()
    toks = generate(p, cfg, prompt, steps=STEPS, sample="greedy")
    dt = time.time() - t0
    print(f"{qmode:>5}: weights {weight_bytes(p) / 2**20:6.1f} MiB | "
          f"{B * STEPS / dt:6.1f} tok/s (incl. compile) | "
          f"first row: {toks[0][:8].tolist()}")
