"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + restart.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

(~100M params on CPU: expect a few seconds/step. Loss should fall from ~9.2
toward the Markov-source entropy.)
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.optim import adamw, cosine_schedule
from repro.train import build_train_step, init_train_state
from repro.train import loop as loop_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/camp_train_100m")
    args = ap.parse_args()

    # qwen3 family at ~100M: 6 layers, d=512, 8 heads, tied embeddings
    cfg = get_config("qwen3-0.6b", n_layers=6, d_model=512, n_heads=8,
                     n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
                     max_seq_len=256)
    opt = adamw(lr=cosine_schedule(1e-3, 30, args.steps), weight_decay=0.01)
    step = build_train_step(cfg, opt)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(state["params"]))
    print(f"params: {n / 1e6:.1f}M")

    data = SyntheticLMData(cfg.vocab_size, batch=16, seq=128, seed=0)
    state, hist = loop_lib.run(step, state, data, steps=args.steps,
                               ckpt_dir=args.ckpt_dir, ckpt_every=100,
                               log_every=20)
    print(f"loss: {np.mean(hist['loss'][:5]):.3f} → "
          f"{np.mean(hist['loss'][-5:]):.3f}")


if __name__ == "__main__":
    main()
