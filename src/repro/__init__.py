"""repro: the CAMP architecture (quantized outer-product GEMM) as a
production-grade JAX training/inference framework."""
__version__ = "0.1.0"
