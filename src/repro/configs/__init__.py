"""Architecture registry: the ten assigned configs + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from repro.configs import (
    jamba_v0_1_52b,
    llama4_maverick_400b_a17b,
    moonshot_v1_16b_a3b,
    musicgen_large,
    pixtral_12b,
    qwen2_0_5b,
    qwen2_72b,
    qwen3_0_6b,
    rwkv6_7b,
    stablelm_12b,
)
from repro.configs.shapes import SHAPES, ShapeSpec, runnable
from repro.models.config import ModelConfig

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        pixtral_12b, musicgen_large, qwen2_72b, stablelm_12b, qwen2_0_5b,
        qwen3_0_6b, rwkv6_7b, moonshot_v1_16b_a3b,
        llama4_maverick_400b_a17b, jamba_v0_1_52b,
    )
}

ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str, *, reduced: bool = False, **overrides) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    cfg = REGISTRY[name]
    if reduced:
        cfg = reduce_config(cfg)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Same-family tiny variant for CPU smoke tests (one fwd/train step)."""
    period = len(cfg.mixer_pattern)
    n_layers = max(2, period) if period > 1 else 2
    if cfg.moe_experts:
        n_layers = max(n_layers, 2 * cfg.moe_period)
    heads = 4 if cfg.n_heads else 0
    kv = 0
    if cfg.n_heads:
        kv = max(1, (cfg.n_kv_heads * heads) // cfg.n_heads)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers, d_model=64,
        n_heads=heads, n_kv_heads=kv, head_dim=16,
        d_ff=128, vocab_size=512,
        moe_experts=min(cfg.moe_experts, 4) if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        # cf = E/k makes cap == T (drop-free): decode then agrees with the
        # full forward (capacity-MoE is otherwise batch-size dependent).
        moe_capacity_factor=(min(cfg.moe_experts, 4) / max(1, min(cfg.moe_top_k, 2))
                             if cfg.moe_experts else 1.25),
        ssm_state_dim=8, ssm_dt_rank=8,
        rwkv_head_dim=16, rwkv_lora_r=8, rwkv_chunk=8,
        max_seq_len=128,
    )
