"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave (attention at
position 4 of every 8-layer block), MoE 16e top-2 every other layer.
[arXiv:2403.19887; hf]. Sub-quadratic → runs the long_500k cell."""
from repro.models.config import ModelConfig

_PERIOD8 = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    mixer_pattern=_PERIOD8,
    moe_experts=16, moe_top_k=2, moe_d_ff=14336, moe_period=2,
    ssm_expand=2, ssm_state_dim=16, ssm_conv_dim=4,
)
