"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, interleaved MoE
(every other layer; gives the 400B-total / 17B-active budget), GQA kv=8,
early-fusion multimodal (frontend out of assigned scope).
[hf:meta-llama/Llama-4-Maverick; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    moe_experts=128, moe_top_k=1, moe_d_ff=8192, moe_period=2,
    rope_theta=5e5,
)
