"""moonshot-v1-16b-a3b [moe] — Moonlight-16B-A3B: 64 experts, top-6,
per-expert d_ff=1408, MHA-16. [hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163840,
    moe_experts=64, moe_top_k=6, moe_d_ff=1408, moe_period=1,
)
