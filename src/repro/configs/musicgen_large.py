"""musicgen-large [audio] — decoder-only over EnCodec tokens (frontend STUB).
[arXiv:2306.05284; hf]. input_specs provides precomputed frame embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    embedding_inputs=True,
)
