"""qwen2-0.5b [dense] — GQA kv=2, QKV bias, tied embeddings.
[arXiv:2407.10671; hf]. NOTE: 14 heads do NOT divide the model=16 mesh axis —
the sharding rules degrade attention activations to replicated (weights still
shard on the flattened 896-wide qkv dim); d_ff=4864 shards 16-way fine."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
)
