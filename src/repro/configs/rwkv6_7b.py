"""rwkv6-7b [ssm] — RWKV6 "Finch": attention-free, data-dependent decay.
[arXiv:2404.05892; hf]. Sub-quadratic → runs the long_500k cell (O(1) decode
state)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=14336, vocab_size=65536,
    mixer_pattern=("rwkv",), rwkv_head_dim=64, rwkv_chunk=32, rwkv_lora_r=64,
)
