"""Assigned input shapes (LM-family: seq_len × global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``. ``long_500k`` requires
sub-quadratic attention: it runs only for ssm/hybrid families (rwkv6-7b,
jamba-v0.1-52b) and is recorded SKIP(sub-quadratic) for pure-attention archs
(see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # 'train' | 'prefill' | 'decode'
    subquadratic_only: bool = False


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode",
                           subquadratic_only=True),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def runnable(arch_family: str, shape: ShapeSpec) -> bool:
    if shape.subquadratic_only:
        return arch_family in SUBQUADRATIC_FAMILIES
    return True
