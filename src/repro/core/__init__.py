"""CAMP core: the paper's contribution as composable JAX ops."""
from repro.core.blocking import BlockConfig, choose_blocks
from repro.core.camp import QMODES, camp_matmul, prepare_weight, qat_matmul, weight_bits
from repro.core.hybrid import hybrid_matmul_i8, hybrid_matmul_w4a8, split_nibbles
from repro.core.quant import (
    INT4_QMAX,
    INT8_QMAX,
    QuantizedTensor,
    dequantize_rowwise,
    fake_quant,
    pack_int4,
    quantize_colwise,
    quantize_rowwise,
    quantize_weight,
    unpack_int4,
)
