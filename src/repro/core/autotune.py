"""Shape-keyed block-size autotuning for the CAMP GEMM kernels.

``choose_blocks`` (the GotoBLAS-analog analytic pick) is a good seed but a
single hardcoded block triple cannot be right for both a 1-token decode GEMM
and a 32k-row prefill GEMM. This module makes block selection a **cache**:

* key — (kernel kind, fused?, M, N, K, backend),
* candidates — ``choose_blocks`` seed plus its neighborhood (bk halved and
  doubled, register tile halved and doubled), filtered by VMEM fit,
* scoring — on a live TPU backend each candidate is timed on synthetic
  operands (median of a few reps); under ``interpret`` / on non-TPU backends
  an analytic roofline model (HBM stream bytes per the kernels' actual
  BlockSpec revisit pattern + MXU flops + per-grid-step overhead) picks the
  winner instead, so tuning is instant and deterministic in tests,
* persistence — winners are written through to a JSON cache
  (``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro/autotune.json``) so a serving
  process never re-tunes a shape another process already paid for.

``ops.gemm_*`` and ``camp_matmul`` use :func:`get_blocks` whenever the caller
does not pass an explicit block triple. Measurement only happens outside jit
tracing (shapes are static there anyway; inside a trace the analytic model or
cache answers).
"""
from __future__ import annotations

import json
import math
import os
import threading
import time
from typing import Callable, Optional, Tuple

from repro.core.blocking import MXU, VMEM_BYTES, BlockConfig, choose_blocks

# v5e roofline constants (same as benchmarks/common.py; duplicated because
# src/ must not import the benchmarks/ harness package).
_PEAK_INT8 = 394e12   # int8 MXU FLOP/s per chip
_HBM_BW = 819e9       # B/s
_STEP_OVERHEAD_S = 1e-6  # per-grid-step issue overhead; penalizes tiny blocks

KINDS = ("i8", "w4", "a4w4")
_KIND_BITS = {"i8": (8, 8), "w4": (4, 8), "a4w4": (4, 4)}  # (w_bits, a_bits)

_lock = threading.Lock()
_mem_cache: dict = {}
_disk_loaded = False


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def clear_cache(*, disk: bool = False) -> None:
    global _disk_loaded
    with _lock:
        _mem_cache.clear()
        _disk_loaded = False
        if disk:
            try:
                os.remove(cache_path())
            except OSError:
                pass


def _load_disk() -> None:
    """Merge the JSON cache into memory once per process (under _lock)."""
    global _disk_loaded
    if _disk_loaded:
        return
    _disk_loaded = True
    try:
        with open(cache_path()) as f:
            on_disk = json.load(f)
    except (OSError, ValueError):
        return
    for key, entry in on_disk.items():
        _mem_cache.setdefault(key, entry)


def _save_disk() -> None:
    """Atomic read-merge-write of the JSON cache (under _lock); best-effort."""
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        merged = {}
        try:
            with open(path) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            pass
        merged.update(_mem_cache)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(merged, f, indent=0, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS etc. — the in-memory cache still works


def _backend() -> str:
    import jax
    return jax.default_backend()


def _key(kind: str, fused: bool, m: int, n: int, k: int, backend: str,
         a_in_bytes: int) -> str:
    # a_in_bytes only shapes the fused kernels' VMEM row panel; unfused
    # kernels stream quantized activations, so it stays out of their key.
    f = f"fused-a{a_in_bytes}B" if fused else "unfused"
    return f"{kind}|{f}|m{m}|n{n}|k{k}|{backend}"


def _fits(kind: str, fused: bool, block: Tuple[int, int, int], k: int,
          a_in_bytes: int, budget: int = VMEM_BYTES // 2) -> bool:
    bm, bn, bk = block
    w_bits, a_bits = _KIND_BITS[kind]
    if fused:
        # Fused kernels hold the full (bm, K) activation row-panel in its
        # storage dtype plus the int8 working block, B double-buffered, the
        # int32 accumulator, the f32 output tile and the (bm, 1) scales.
        from repro.kernels.padding import round_up
        kp = round_up(k, bk)
        a = bm * kp * a_in_bytes + bm * bk
        b = 2 * (bk * bn * w_bits // 8)
        return a + b + bm * bn * 8 + bm * 4 <= budget
    return BlockConfig(bm, bn, bk).vmem_bytes(w_bits, a_bits) <= budget


def candidates(kind: str, m: int, n: int, k: int, *, fused: bool = False,
               a_in_bytes: int = 4) -> list:
    """Seed from choose_blocks, then explore its blocking neighborhood."""
    w_bits, a_bits = _KIND_BITS[kind]
    seed = choose_blocks(m, n, k, w_bits=w_bits, a_bits=a_bits)
    cands = []

    def add(bm, bn, bk):
        bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
        bm, bn, bk = max(bm, 1), max(bn, 1), max(bk, 1)
        if kind != "i8":
            bk = max(2, bk - bk % 2)  # packed-K kernels need even bk
        blk = (bm, bn, bk)
        if blk not in cands and _fits(kind, fused, blk, k, a_in_bytes):
            cands.append(blk)

    add(seed.bm, seed.bn, seed.bk)
    add(seed.bm, seed.bn, seed.bk * 2)
    add(seed.bm, seed.bn, max(MXU, seed.bk // 2))
    add(seed.bm * 2, seed.bn * 2, seed.bk)
    add(max(MXU, seed.bm // 2), max(MXU, seed.bn // 2), seed.bk)
    add(max(MXU, seed.bm // 2), seed.bn, seed.bk * 2)
    if fused and not cands:
        # Large-K fused panel: shrink bm until the row-panel fits VMEM.
        bm = seed.bm
        while bm > 1:
            bm //= 2
            add(bm, seed.bn, seed.bk)
            if cands:
                break
    if not cands:
        bm, bn, bk = min(seed.bm, m), min(seed.bn, n), min(seed.bk, k)
        if kind != "i8":
            bk = max(2, bk - bk % 2)
        cands.append((bm, bn, bk))  # last resort: seed, budget notwithstanding
    return cands


def model_time_s(kind: str, m: int, n: int, k: int,
                 block: Tuple[int, int, int], *, fused: bool = False,
                 a_in_bytes: int = 4) -> float:
    """Analytic v5e time for one GEMM under this blocking.

    HBM bytes follow the kernels' BlockSpec revisit pattern: B is re-streamed
    once per grid row (M/bm); unfused A is re-streamed once per grid column
    (N/bn); the fused A row-panel's index map is constant in (j, k), so it
    streams exactly once.
    """
    from repro.kernels.padding import round_up
    bm, bn, bk = block
    w_bits, a_bits = _KIND_BITS[kind]
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    steps = (mp // bm) * (np_ // bn) * (kp // bk)
    if fused:
        a_bytes = mp * kp * a_in_bytes                      # once per i-row
    else:
        a_bytes = mp * kp * (a_bits / 8) * (np_ // bn)      # once per j-col
    b_bytes = kp * np_ * (w_bits / 8) * (mp // bm)
    o_bytes = mp * np_ * 4.0
    flops = 2.0 * mp * np_ * kp
    return max((a_bytes + b_bytes + o_bytes) / _HBM_BW, flops / _PEAK_INT8) \
        + steps * _STEP_OVERHEAD_S


def _measure_time_s(kind: str, m: int, n: int, k: int,
                    block: Tuple[int, int, int], *, fused: bool,
                    a_in_bytes: int = 2, reps: int = 3) -> float:
    """Median wall-clock of the real kernel on synthetic operands (TPU path)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.camp_gemm import camp_gemm_i8
    from repro.kernels.camp_gemm_fused import (camp_gemm_fused_w4a4,
                                               camp_gemm_fused_w4a8,
                                               camp_gemm_fused_w8a8)
    from repro.kernels.camp_gemm_w4 import camp_gemm_a4w4, camp_gemm_w4

    bm, bn, bk = block
    sb = jnp.ones((1, n), jnp.float32)
    kw = dict(block_m=bm, block_n=bn, block_k=bk)
    if fused:
        x = jnp.zeros((m, k), jnp.bfloat16 if a_in_bytes == 2 else jnp.float32)
        bq = jnp.zeros(((k if kind == "i8" else k // 2), n), jnp.int8)
        fn = {"i8": camp_gemm_fused_w8a8, "w4": camp_gemm_fused_w4a8,
              "a4w4": camp_gemm_fused_w4a4}[kind]
        call = lambda: fn(x, bq, sb, **kw)  # noqa: E731
    else:
        sa = jnp.ones((m, 1), jnp.float32)
        if kind == "i8":
            a = jnp.zeros((m, k), jnp.int8)
            bq = jnp.zeros((k, n), jnp.int8)
            call = lambda: camp_gemm_i8(a, bq, sa, sb, **kw)  # noqa: E731
        elif kind == "w4":
            a = jnp.zeros((m, k), jnp.int8)
            bq = jnp.zeros((k // 2, n), jnp.int8)
            call = lambda: camp_gemm_w4(a, bq, sa, sb, **kw)  # noqa: E731
        else:
            a = jnp.zeros((m, k // 2), jnp.int8)
            bq = jnp.zeros((k // 2, n), jnp.int8)
            call = lambda: camp_gemm_a4w4(a, bq, sa, sb, **kw)  # noqa: E731
    jax.block_until_ready(call())  # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def flush() -> None:
    """Write the in-memory cache through to disk (for ``save=False`` loops)."""
    with _lock:
        _save_disk()


def has_cached(kind: str, m: int, n: int, k: int, *, fused: bool = False,
               a_in_bytes: int = 4) -> bool:
    """Is (kind, fused, m, n, k) already tuned for this backend?

    Lets warmup loops skip shapes a previous process (or an earlier
    enumeration pass in the same warmup) already paid for, instead of
    re-tuning — :func:`tune` itself always re-scores.
    """
    key = _key(kind, fused, m, n, k, _backend(), a_in_bytes)
    with _lock:
        _load_disk()
        return key in _mem_cache


def tune(kind: str, m: int, n: int, k: int, *, fused: bool = False,
         a_in_bytes: int = 4, measure: Optional[bool] = None,
         timer: Optional[Callable] = None,
         save: bool = True) -> Tuple[int, int, int]:
    """Pick the best block for (kind, fused, m, n, k) and cache it.

    ``measure=None`` → measure iff running on a real TPU backend. ``timer``
    overrides the per-candidate scorer (tests use this). ``save=False``
    defers the disk write — callers tuning many shapes in a loop should
    :func:`flush` once at the end instead of rewriting the JSON per shape.
    """
    if kind not in KINDS:
        raise ValueError(f"kind={kind!r} not in {KINDS}")
    backend = _backend()
    if measure is None:
        measure = backend == "tpu"
    key = _key(kind, fused, m, n, k, backend, a_in_bytes)

    best, best_t, source = None, float("inf"), "model"
    cands = candidates(kind, m, n, k, fused=fused, a_in_bytes=a_in_bytes)
    for blk in cands:
        src = "model"
        if timer is not None:
            t = timer(blk)
        elif measure:
            try:
                t = _measure_time_s(kind, m, n, k, blk, fused=fused,
                                    a_in_bytes=a_in_bytes)
                src = "measured"
            except Exception:
                # Mosaic rejected this candidate — never let it compete (an
                # analytic score would beat every *measured* wall-clock and a
                # non-compiling block would get cached).
                continue
        else:
            t = model_time_s(kind, m, n, k, blk, fused=fused,
                             a_in_bytes=a_in_bytes)
        if t < best_t:
            best, best_t, source = blk, t, src
    if best is None:
        # Every candidate failed to compile — pick the analytic best so the
        # caller's error is the kernel's own (reproducible) compile error.
        best = min(cands, key=lambda b: model_time_s(
            kind, m, n, k, b, fused=fused, a_in_bytes=a_in_bytes))
        best_t = model_time_s(kind, m, n, k, best, fused=fused,
                              a_in_bytes=a_in_bytes)

    with _lock:
        _load_disk()
        _mem_cache[key] = {"block": list(best), "source": source,
                           "t_us": best_t * 1e6}
        if save:
            _save_disk()
    return best


# ---------------------------------------------------------------------------
# Paged-attention page-size tuning (same persistent cache, its own key space)
# ---------------------------------------------------------------------------
PAGE_SIZES = (8, 16, 32, 64, 128)


def model_paged_decode_time_s(batch: int, kv_heads: int, head_dim: int,
                              mean_len: int, page_size: int) -> float:
    """Analytic v5e time for one layer's paged int8 decode-attention step.

    HBM term: each sequence streams its occupied pages (k+v int8 +
    per-token f32 scales); the expected half-empty last page charges
    fragmentation to large pages. Overhead term: one grid step per
    (seq, kv head, page) charges the per-step issue cost to small pages.
    """
    pages = mean_len / page_size + 0.5
    page_bytes = 2 * page_size * (head_dim + 4)   # int8 k+v + per-token scales
    hbm = batch * kv_heads * pages * page_bytes
    steps = batch * kv_heads * math.ceil(mean_len / page_size + 0.5)
    return hbm / _HBM_BW + steps * _STEP_OVERHEAD_S


def get_page_size(kv_heads: int, head_dim: int, mean_len: int,
                  batch: int = 8, *, timer: Optional[Callable] = None,
                  save: bool = True) -> int:
    """Cached KV page-size pick for a serving shape; tunes on first sight.

    Lives in the same JSON cache as the GEMM blocks (its own ``pattn|`` key
    space), so a pool size tuned by one serving process is reused by the
    next. ``timer`` overrides the analytic scorer (tests use this).
    """
    key = (f"pattn|kv{kv_heads}|hd{head_dim}|len{mean_len}|b{batch}"
           f"|{_backend()}")
    with _lock:
        _load_disk()
        hit = _mem_cache.get(key)
    if hit is not None:
        return int(hit["page_size"])
    score = timer or (lambda ps: model_paged_decode_time_s(
        batch, kv_heads, head_dim, mean_len, ps))
    scores = {ps: score(ps) for ps in PAGE_SIZES}
    best = min(scores, key=scores.get)
    with _lock:
        _load_disk()
        _mem_cache[key] = {"page_size": int(best),
                           "source": "timer" if timer else "model",
                           "t_us": scores[best] * 1e6}
        if save:
            _save_disk()
    return int(best)


# ---------------------------------------------------------------------------
# Chunked paged-prefill tuning (same persistent cache, ``pprefill|`` keys)
# ---------------------------------------------------------------------------
PREFILL_CHUNKS = (64, 128, 256, 512)
PREFILL_PAGES_PER_STEP = (1, 2, 4, 8)


def model_paged_prefill_time_s(kv_heads: int, head_dim: int, page_size: int,
                               mean_len: int, chunk: int,
                               pages_per_step: int) -> float:
    """Analytic v5e per-token time of one layer's chunked paged prefill.

    Each chunk re-streams the sequence's cached pages once (k+v int8 +
    per-token scales), so bigger chunks amortize the restream; one grid step
    covers ``pages_per_step`` pages, so bigger steps amortize issue
    overhead. The (chunk × kv-block) f32 score tile must fit the online-
    softmax working set in VMEM, which bounds both from above.
    """
    n_pages = mean_len / page_size + 0.5
    page_bytes = 2 * page_size * (head_dim + 4)   # int8 k+v + per-token scales
    hbm = kv_heads * n_pages * page_bytes + chunk * 2 * kv_heads * head_dim * 2
    steps = kv_heads * math.ceil(n_pages / pages_per_step)
    scores = chunk * pages_per_step * page_size * 4    # f32 score tile
    acc = chunk * head_dim * 4 * 2                     # acc + q resident
    if scores + acc > VMEM_BYTES // 4:
        return float("inf")
    return (hbm / _HBM_BW + steps * _STEP_OVERHEAD_S) / chunk


def get_prefill_params(kv_heads: int, head_dim: int, page_size: int,
                       mean_len: int, *, timer: Optional[Callable] = None,
                       save: bool = True) -> Tuple[int, int]:
    """Cached (chunk_tokens, pages_per_step) pick for chunked paged prefill.

    Lives in the same JSON cache as the GEMM blocks (its own ``pprefill|``
    key space). ``timer(chunk, pages_per_step)`` overrides the analytic
    scorer (tests use this).
    """
    key = (f"pprefill|kv{kv_heads}|hd{head_dim}|ps{page_size}"
           f"|len{mean_len}|{_backend()}")
    with _lock:
        _load_disk()
        hit = _mem_cache.get(key)
    if hit is not None:
        return int(hit["chunk"]), int(hit["pages_per_step"])
    score = timer or (lambda c, pp: model_paged_prefill_time_s(
        kv_heads, head_dim, page_size, mean_len, c, pp))
    scores = {(c, pp): score(c, pp)
              for c in PREFILL_CHUNKS for pp in PREFILL_PAGES_PER_STEP}
    best = min(scores, key=scores.get)
    with _lock:
        _load_disk()
        _mem_cache[key] = {"chunk": int(best[0]),
                           "pages_per_step": int(best[1]),
                           "source": "timer" if timer else "model",
                           "t_us": scores[best] * 1e6}
        if save:
            _save_disk()
    return int(best[0]), int(best[1])


# ---------------------------------------------------------------------------
# Speculative-decoding window tuning (same persistent cache, ``spec|`` keys)
# ---------------------------------------------------------------------------
SPEC_GAMMAS = (1, 2, 3, 4, 6, 8)
DEFAULT_SPEC_GAMMA = 4
# Marginal cost of one extra verify row relative to a whole decode step.
# Decode is memory-bound: the weight/cache stream is paid once per forward
# whether it scores 1 row or γ+1, so extra rows cost only their (tiny)
# compute slice — the whole reason speculation pays.
_SPEC_ROW_COST = 0.06


def expected_spec_tokens(gamma: int, acceptance: float) -> float:
    """E[tokens emitted per verify step] under per-token acceptance rate
    ``acceptance``: 1 + a + a² + … + a^γ (the classic geometric series —
    the step always emits at least one token)."""
    a = min(max(acceptance, 0.0), 1.0)
    if a >= 1.0:
        return float(gamma + 1)
    return (1.0 - a ** (gamma + 1)) / (1.0 - a)


def get_spec_gamma(acceptance: float, *, draft_cost: float = 0.0,
                   timer: Optional[Callable] = None,
                   save: bool = True) -> int:
    """Cached speculation-window pick from measured acceptance × cost.

    Scores each candidate γ by expected tokens per unit cost, where one
    verify step costs ``1 + _SPEC_ROW_COST·γ + draft_cost·γ`` decode-step
    equivalents (``draft_cost``: the drafter's per-token cost ratio — 0 for
    n-gram lookup, ~0.25 for a small draft model). Acceptance is bucketed
    to 0.05 so the ``spec|`` key space stays bounded; ``timer(gamma)``
    overrides the analytic scorer (tests use this). Lives in the same JSON
    cache as the GEMM blocks, so a window tuned by one serving process is
    reused by the next.
    """
    bucket = round(min(max(acceptance, 0.0), 0.95) * 20) / 20
    key = f"spec|acc{bucket:.2f}|dc{draft_cost:.2f}|{_backend()}"
    with _lock:
        _load_disk()
        hit = _mem_cache.get(key)
    if hit is not None:
        return int(hit["gamma"])
    score = timer or (lambda g: -expected_spec_tokens(g, bucket)
                      / (1.0 + _SPEC_ROW_COST * g + draft_cost * g))
    scores = {g: score(g) for g in SPEC_GAMMAS}
    best = min(scores, key=scores.get)
    with _lock:
        _load_disk()
        _mem_cache[key] = {"gamma": int(best),
                           "source": "timer" if timer else "model",
                           "score": scores[best]}
        if save:
            _save_disk()
    return int(best)


def get_blocks(kind: str, m: int, n: int, k: int, *, fused: bool = False,
               a_in_bytes: int = 4,
               allow_measure: bool = False) -> Tuple[int, int, int]:
    """Cached block lookup; tunes (and persists) on first sight of a shape.

    ``allow_measure=False`` keeps cold-cache lookups cheap and trace-safe:
    analytic pick now, and a serving warmup (:func:`tune` with measurement)
    can overwrite the entry later.
    """
    backend = _backend()
    key = _key(kind, fused, m, n, k, backend, a_in_bytes)
    with _lock:
        _load_disk()
        hit = _mem_cache.get(key)
    if hit is not None:
        return tuple(hit["block"])
    return tune(kind, m, n, k, fused=fused, a_in_bytes=a_in_bytes,
                measure=(None if allow_measure else False))
