"""GotoBLAS-analog block-size selection for the CAMP Pallas kernels.

The paper chooses ``k_c/m_c/n_R`` so each packed panel lands in the right
cache level (L3→L2→L1→registers, Fig. 3). The TPU analogue is one level —
HBM→VMEM — plus MXU shape alignment:

* A-block (bm×bk int8), B-block (bk×bn int8 or bk/2×bn packed int4) and the
  int32 accumulator (bm×bn) must fit VMEM together, double-buffered.
* MXU is 128×128; all dims multiples of 128, minor dims ≥ 256 preferred so the
  int8 lanes stay full (int8 tiling is (32, 128) per register).
* Larger bk amortizes the accumulator flush (the paper's "kc/16 iterations per
  store"), so we maximize bk first — same reasoning as GotoBLAS maximizing the
  L1-resident panel height.
"""
from __future__ import annotations

import dataclasses

VMEM_BYTES = 16 * 2**20  # v5e VMEM per core
MXU = 128


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    bm: int
    bn: int
    bk: int

    def vmem_bytes(self, w_bits: int = 8, a_bits: int = 8) -> int:
        """VMEM footprint of one grid step: double-buffered quantized A/B
        input streams + the int32 accumulator + the output tile."""
        a = self.bm * self.bk * a_bits // 8
        b = self.bk * self.bn * w_bits // 8
        acc = self.bm * self.bn * 4
        out = self.bm * self.bn * 4
        # double-buffered input streams
        return 2 * (a + b) + acc + out


def _round_down_mxu(x: int) -> int:
    return max(MXU, (x // MXU) * MXU)


def choose_blocks(m: int, n: int, k: int, *, w_bits: int = 8, a_bits: int = 8,
                  vmem_budget: int = VMEM_BYTES // 2) -> BlockConfig:
    """Pick (bm, bn, bk) fitting ``vmem_budget``, maximizing bk then bm=bn.

    Mirrors GotoBLAS: deepest-loop panel (k_c) first, then the register tile.
    """
    bm = min(_round_down_mxu(m), 256)
    bn = min(_round_down_mxu(n), 256)
    bk = min(_round_down_mxu(k), 2048)
    while BlockConfig(bm, bn, bk).vmem_bytes(w_bits, a_bits) > vmem_budget and bk > MXU:
        bk //= 2
    while BlockConfig(bm, bn, bk).vmem_bytes(w_bits, a_bits) > vmem_budget and bm > MXU:
        bm //= 2
        bn //= 2

    # Prefer a block that divides the dim (zero padding), but never shrink
    # below the MXU tile to get there: the kernels pad edge blocks, and a
    # padded 128-wide tile beats a degenerate 2-wide one by orders of
    # magnitude in grid steps.
    def _fit(b, dim):
        b = min(b, dim)
        c = b
        while dim % c and c > MXU:
            c //= 2
        return max(c if dim % c == 0 else b, 1)
    return BlockConfig(_fit(bm, m), _fit(bn, n), _fit(bk, k))
