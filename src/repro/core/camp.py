"""CAMP public API — the paper's technique as a composable JAX op.

``camp_matmul(x, w)`` is the drop-in replacement for ``x @ W`` in any model
layer: it dynamically quantizes the activations (per-token rowwise absmax, the
A-panel of the paper's micro-kernel), runs the integer outer-product GEMM with
int32 accumulation, and applies the Cartesian scale epilogue. Weights arrive
pre-quantized as :class:`repro.core.quant.QuantizedTensor` (per-output-channel
scales; int8, or int4 packed 2-per-byte).

Quantization modes (``qmode``):

  =========  =========================  ==============================
  qmode      storage                    compute
  =========  =========================  ==============================
   none      bf16/f32 weights            bf16 matmul (baseline)
  w8a8       int8 W (1 B/param)          int8×int8→int32 (CAMP kernel)
  w4a8       packed int4 W (0.5 B)       int8×int4→int32 (hybrid, 2× rate)
  w4a4       packed int4 W + int4 A      int4×int4→int32 (4× pairings)
  w8a16      int8 W                      dequant → bf16 matmul (weight-only)
  w4a16      packed int4 W               dequant → bf16 matmul (weight-only)
  =========  =========================  ==============================

The integer modes are the paper's contribution; the weight-only modes are the
bandwidth-only baseline the roofline analysis compares against.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, quantize_weight
from repro.kernels import ops

QMODES = ("none", "w8a8", "w4a8", "w4a4", "w8a16", "w4a16")


def weight_bits(qmode: str) -> Optional[int]:
    if qmode == "none":
        return None
    return 4 if qmode.startswith("w4") else 8


def prepare_weight(w: jax.Array, qmode: str):
    """Quantize a (K, N) weight for ``qmode`` (identity for 'none')."""
    if qmode not in QMODES:
        raise ValueError(f"qmode={qmode!r} not in {QMODES}")
    if qmode == "none":
        return w
    return quantize_weight(w, bits=weight_bits(qmode))


def camp_matmul(
    x: jax.Array,
    w,
    *,
    qmode: str = "w8a8",
    impl: str = "auto",
    out_dtype=None,
    block=(256, 256, 512),
) -> jax.Array:
    """Quantized matmul ``x @ W`` via the CAMP pipeline.

    ``x``: (..., K) float; ``w``: QuantizedTensor (K, N) (or raw array when
    qmode='none'). Returns (..., N) in ``out_dtype`` (defaults to x.dtype).
    """
    if qmode not in QMODES:
        raise ValueError(f"qmode={qmode!r} not in {QMODES}")
    out_dtype = out_dtype or x.dtype

    if qmode == "none":
        w_arr = w.dequantize() if isinstance(w, QuantizedTensor) else w
        return jnp.matmul(x, w_arr.astype(x.dtype)).astype(out_dtype)

    assert isinstance(w, QuantizedTensor), type(w)
    lead = x.shape[:-1]
    k = x.shape[-1]
    assert w.shape[0] == k, (x.shape, w.shape)
    x2 = x.reshape(-1, k)

    if qmode in ("w8a16", "w4a16"):
        # Weight-only: bandwidth win, bf16 MXU compute.
        w_deq = w.dequantize().astype(x.dtype)
        y = jnp.matmul(x2, w_deq)
    elif qmode == "w8a8":
        a_q, a_s = ops.quantize_rowwise(x2, bits=8, impl=impl)
        y = ops.gemm_i8(a_q, w.q, a_s, w.scale, out_dtype=out_dtype,
                        impl=impl, block=block)
    elif qmode == "w4a8":
        a_q, a_s = ops.quantize_rowwise(x2, bits=8, impl=impl)
        y = ops.gemm_w4(a_q, w.q, a_s, w.scale, out_dtype=out_dtype,
                        impl=impl, block=block)
    else:  # w4a4
        from repro.core.quant import pack_int4
        a_q, a_s = ops.quantize_rowwise(x2, bits=4, impl=impl)
        a_packed = pack_int4(a_q.T).T  # pack along K (last axis)
        y = ops.gemm_a4w4(a_packed, w.q, k, a_s, w.scale, out_dtype=out_dtype,
                          impl=impl, block=block)
    return y.reshape(*lead, w.shape[1]).astype(out_dtype)


def qat_matmul(x: jax.Array, w: jax.Array, *, bits: int = 8) -> jax.Array:
    """Training-side fake-quantized matmul (straight-through gradients).

    Simulates CAMP numerics in the forward pass while keeping bf16 autodiff —
    the standard QAT recipe for producing weights that survive PTQ to
    int8/int4.
    """
    from repro.core.quant import fake_quant
    xq = fake_quant(x, bits)
    wq = fake_quant(w.T, bits).T  # per-output-channel (over K) like PTQ
    return jnp.matmul(xq, wq)
