"""CAMP public API — the paper's technique as a composable JAX op.

``camp_matmul(x, w)`` is the drop-in replacement for ``x @ W`` in any model
layer: it dynamically quantizes the activations (per-token rowwise absmax, the
A-panel of the paper's micro-kernel), runs the integer outer-product GEMM with
int32 accumulation, and applies the Cartesian scale epilogue. Weights arrive
pre-quantized as :class:`repro.core.quant.QuantizedTensor` (per-output-channel
scales; int8, or int4 packed 2-per-byte).

Quantization modes (``qmode``):

  =========  =========================  =========================================
  qmode      storage                    compute
  =========  =========================  =========================================
   none      bf16/f32 weights            bf16 matmul (baseline)
  w8a8       int8 W (1 B/param)          fused quantize→int8×int8→int32 kernel
  w4a8       packed int4 W (0.5 B)       fused quantize→int8×int4→int32 (2× rate)
  w4a4       packed int4 W + int4 A      fused quantize→int4×int4→int32 (4× pair)
  w8a16      int8 W                      dequant → bf16 matmul (weight-only)
  w4a16      packed int4 W               dequant → bf16 matmul (weight-only)
  =========  =========================  =========================================

The integer modes are the paper's contribution; the weight-only modes are the
bandwidth-only baseline the roofline analysis compares against.

For the integer modes the default path is the **fused kernel family**
(:mod:`repro.kernels.camp_gemm_fused`): activation quantization happens on the
VMEM-resident row panel inside the GEMM, so the int8/int4 activation payload
and its scales never exist in HBM (``fused=False`` restores the two-kernel
quantize→GEMM composition, which remains the fused path's bit-exactness
witness). Elementwise tails — ``epilogue=`` with ``bias=``/``operand=``, see
:mod:`repro.kernels.epilogue` — run on the f32 accumulator inside the kernel
flush, and block sizes come from the :mod:`repro.core.autotune` cache unless
``block=`` is given explicitly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, quantize_weight
from repro.kernels import ops
from repro.kernels.epilogue import apply_epilogue, validate_epilogue

QMODES = ("none", "w8a8", "w4a8", "w4a4", "w8a16", "w4a16")
INT_QMODES = ("w8a8", "w4a8", "w4a4")


def weight_bits(qmode: str) -> Optional[int]:
    if qmode == "none":
        return None
    return 4 if qmode.startswith("w4") else 8


def prepare_weight(w: jax.Array, qmode: str):
    """Quantize a (K, N) weight for ``qmode`` (identity for 'none')."""
    if qmode not in QMODES:
        raise ValueError(f"qmode={qmode!r} not in {QMODES}")
    if qmode == "none":
        return w
    return quantize_weight(w, bits=weight_bits(qmode))


def camp_matmul(
    x: jax.Array,
    w,
    *,
    qmode: str = "w8a8",
    impl: str = "auto",
    out_dtype=None,
    block=None,
    fused: Optional[bool] = None,
    epilogue: str = "none",
    bias: Optional[jax.Array] = None,      # (N,) for the 'bias' stage
    operand: Optional[jax.Array] = None,   # (..., N) for 'residual'/'mul'
) -> jax.Array:
    """Quantized matmul ``x @ W`` via the CAMP pipeline.

    ``x``: (..., K) float; ``w``: QuantizedTensor (K, N) (or raw array when
    qmode='none'). Returns (..., N) in ``out_dtype`` (defaults to x.dtype).

    ``fused=None`` → fused quantize-in-kernel path for the integer qmodes
    (ignored for 'none'/weight-only, which have no activation quantization).
    ``block=None`` → autotuned block sizes. ``epilogue``/``bias``/``operand``
    fuse elementwise tails into the kernel flush.
    """
    if qmode not in QMODES:
        raise ValueError(f"qmode={qmode!r} not in {QMODES}")
    out_dtype = out_dtype or x.dtype
    stages = validate_epilogue(epilogue, bias, operand)

    def _finish_float(y):
        # Float paths (baseline / weight-only): epilogue as plain XLA tail.
        if stages:
            y = apply_epilogue(
                y.astype(jnp.float32), stages,
                bias=None if bias is None else bias.reshape(1, -1),
                operand=None if operand is None else operand.reshape(y.shape))
        return y.astype(out_dtype)

    if qmode == "none":
        w_arr = w.dequantize() if isinstance(w, QuantizedTensor) else w
        return _finish_float(jnp.matmul(x, w_arr.astype(x.dtype)))

    assert isinstance(w, QuantizedTensor), type(w)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[1]
    assert w.shape[0] == k, (x.shape, w.shape)
    x2 = x.reshape(-1, k)
    opd2 = None if operand is None else operand.reshape(-1, n)

    if qmode in ("w8a16", "w4a16"):
        # Weight-only: bandwidth win, bf16 MXU compute.
        w_deq = w.dequantize().astype(x.dtype)
        y = _finish_float(jnp.matmul(x2, w_deq))
        return y.reshape(*lead, n)

    if fused is None:
        fused = True
    kw = dict(out_dtype=out_dtype, impl=impl, block=block, epilogue=epilogue,
              bias=bias, operand=opd2)
    if fused:
        fn = {"w8a8": ops.gemm_i8_fused, "w4a8": ops.gemm_w4_fused,
              "w4a4": ops.gemm_a4w4_fused}[qmode]
        y = fn(x2, w.q, w.scale, **kw)
    elif qmode == "w8a8":
        a_q, a_s = ops.quantize_rowwise(x2, bits=8, impl=impl)
        y = ops.gemm_i8(a_q, w.q, a_s, w.scale, **kw)
    elif qmode == "w4a8":
        a_q, a_s = ops.quantize_rowwise(x2, bits=8, impl=impl)
        y = ops.gemm_w4(a_q, w.q, a_s, w.scale, **kw)
    else:  # w4a4
        from repro.core.quant import pack_int4
        a_q, a_s = ops.quantize_rowwise(x2, bits=4, impl=impl)
        a_packed = pack_int4(a_q.T).T  # pack along K (last axis)
        y = ops.gemm_a4w4(a_packed, w.q, k, a_s, w.scale, **kw)
    return y.reshape(*lead, n).astype(out_dtype)


def qat_matmul(x: jax.Array, w: jax.Array, *, bits: int = 8) -> jax.Array:
    """Training-side fake-quantized matmul (straight-through gradients).

    Simulates CAMP numerics in the forward pass while keeping bf16 autodiff —
    the standard QAT recipe for producing weights that survive PTQ to
    int8/int4.
    """
    from repro.core.quant import fake_quant
    xq = fake_quant(x, bits)
    wq = fake_quant(w.T, bits).T  # per-output-channel (over K) like PTQ
    return jnp.matmul(xq, wq)
