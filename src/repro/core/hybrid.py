"""Hybrid (divide-and-conquer) multiplier — the paper's §3, in matmul algebra.

The CAMP hardware builds every 8-bit multiplier out of four 4-bit multipliers
(Karatsuba-style split, eq. (1)-(2) of the paper):

    A = a1·2^4 + a0,  B = b1·2^4 + b0
    A·B = (a1·b1)·2^8 + (a1·b0 + a0·b1)·2^4 + a0·b0

with ``a1`` the *signed* high nibble (arithmetic shift) and ``a0`` the
*unsigned* low nibble. Because matrix multiplication is linear, the identity
lifts from scalars to whole GEMMs: an int8×int8→int32 GEMM equals a shifted sum
of four int4-operand GEMMs. This module implements that lift.

On the paper's hardware this is what makes int4 run at 2× int8 throughput with
the *same* silicon. On TPU, int4 matmul units are MXU-native (v5e+); the value
of the decomposition here is (a) a bit-exact correctness witness that the
algebra transfers, and (b) the mixed-precision path ``w4a8`` = two int4-range
GEMMs instead of four.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def split_nibbles(x: jax.Array):
    """Split int8 into (signed_high, unsigned_low) nibbles, as int8.

    ``x == hi * 16 + lo`` with ``hi ∈ [-8, 7]`` and ``lo ∈ [0, 15]``.
    """
    hi = (x.astype(jnp.int8) >> 4).astype(jnp.int8)          # arithmetic shift
    lo = (x.astype(jnp.int8) & 0x0F).astype(jnp.int8)         # unsigned low
    return hi, lo


def _dot_i32(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def hybrid_matmul_i8(a: jax.Array, b: jax.Array) -> jax.Array:
    """int8 (M,K) × int8 (K,N) → int32, composed from four int4-range GEMMs.

    Bit-exact equal to ``_dot_i32(a, b)``; tested exhaustively over the full
    int8×int8 scalar square and property-tested on matrices.
    """
    ah, al = split_nibbles(a)
    bh, bl = split_nibbles(b)
    hh = _dot_i32(ah, bh)
    hl = _dot_i32(ah, bl)
    lh = _dot_i32(al, bh)
    ll = _dot_i32(al, bl)
    return (hh << 8) + ((hl + lh) << 4) + ll


def hybrid_matmul_w4a8(a: jax.Array, b4: jax.Array) -> jax.Array:
    """int8 activations (M,K) × int4-valued int8 weights (K,N) → int32.

    Two int4-range GEMMs (the weight already fits a nibble), i.e. the 2×
    throughput point of the paper's hybrid multiplier.
    """
    ah, al = split_nibbles(a)
    return (_dot_i32(ah, b4) << 4) + _dot_i32(al, b4)
