"""Quantization primitives for the CAMP technique.

Symmetric integer quantization in the style the paper targets (int8 and int4),
plus the packed-int4 storage format the TPU adaptation uses.

Conventions
-----------
* Weights ``(K, N)`` are quantized **per output channel** (one scale per column,
  absmax over K) — matches gemmlowp/QNNPACK per-channel practice.
* Activations ``(M, K)`` are quantized **per row** (per token) dynamically.
* int8 values live in [-127, 127] (symmetric, -128 excluded so the hybrid
  decomposition and negation are exact).
* int4 values live in [-7, 7] and are **packed two per int8 byte** along the
  contraction (K) axis, low nibble = even K index. The CPU backend cannot lower
  native ``jnp.int4`` dots, and on TPU the packed form is what saves HBM
  bandwidth — the kernel unpacks in VMEM (the paper's "no pack/unpack
  instruction overhead" maps to "unpack is free relative to HBM").
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

INT8_QMAX = 127
INT4_QMAX = 7

__all__ = [
    "INT8_QMAX",
    "INT4_QMAX",
    "QuantizedTensor",
    "quantize_rowwise",
    "quantize_colwise",
    "dequantize_rowwise",
    "pack_int4",
    "unpack_int4",
    "quantize_weight",
    "fake_quant",
]


def _qmax(bits: int) -> int:
    if bits == 8:
        return INT8_QMAX
    if bits == 4:
        return INT4_QMAX
    raise ValueError(f"unsupported bits={bits}; CAMP supports 8 and 4")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A quantized weight: integer payload + f32 scales + static metadata.

    ``q`` is int8. For ``bits=4`` the payload is packed 2-per-byte along axis 0
    (the contraction axis), so ``q.shape == (K // 2, N)`` while
    ``shape == (K, N)`` records the logical shape.
    ``scale`` has shape ``(1, N)`` (per output channel).
    """

    q: jax.Array
    scale: jax.Array
    bits: int
    shape: tuple  # logical (K, N)

    def tree_flatten(self):
        return (self.q, self.scale), (self.bits, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        bits, shape = aux
        return cls(q=q, scale=scale, bits=bits, shape=shape)

    @property
    def dtype(self):  # logical compute dtype of the dequantized weight
        return self.scale.dtype

    def dequantize(self) -> jax.Array:
        if self.bits == 4:
            w = unpack_int4(self.q, self.shape[0])
        else:
            w = self.q
        return w.astype(self.scale.dtype) * self.scale

    def memory_bytes(self) -> int:
        return int(np.prod(self.q.shape)) + 4 * int(np.prod(self.scale.shape))


def quantize_rowwise(x: jax.Array, bits: int = 8):
    """Symmetric per-row (absmax over the last axis) quantization.

    Returns ``(q_int8, scale)`` with ``scale.shape == x.shape[:-1] + (1,)`` in
    float32 and ``x ≈ q * scale``. Used for dynamic activation quantization.
    """
    qmax = _qmax(bits)
    # |x| reduced in the input dtype (exact for max), f32 upcast only inside
    # the single rounding chain — avoids materializing an f32 copy of x.
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale


def quantize_colwise(w: jax.Array, bits: int = 8):
    """Symmetric per-column (absmax over axis 0) quantization for weights (K, N).

    Returns ``(q_int8, scale)`` with ``scale.shape == (1, N)`` float32.
    """
    qmax = _qmax(bits)
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale


def dequantize_rowwise(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale.astype(dtype)


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4-valued int8 array 2-per-byte along axis 0.

    ``q``: int8 in [-8, 7], first dim even. Row ``2i`` goes to the low nibble,
    row ``2i+1`` to the high nibble of output row ``i``.
    """
    if q.shape[0] % 2 != 0:
        raise ValueError(f"K={q.shape[0]} must be even to pack int4")
    lo = q[0::2]
    hi = q[1::2]
    return ((hi.astype(jnp.int8) << 4) | (lo.astype(jnp.int8) & 0x0F)).astype(jnp.int8)


def unpack_int4(packed: jax.Array, k: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_int4` (sign-extending both nibbles)."""
    # Arithmetic shifts on int8 sign-extend; (x << 4) >> 4 sign-extends the low
    # nibble.
    lo = ((packed.astype(jnp.int8) << 4).astype(jnp.int8) >> 4).astype(jnp.int8)
    hi = (packed.astype(jnp.int8) >> 4).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=1).reshape((2 * packed.shape[0],) + packed.shape[1:])
    if k is not None:
        out = out[:k]
    return out


def quantize_weight(w: jax.Array, bits: int = 8) -> QuantizedTensor:
    """Quantize a weight matrix (K, N) to a :class:`QuantizedTensor`."""
    if w.ndim != 2:
        raise ValueError(f"quantize_weight expects 2-D (K, N); got {w.shape}")
    q, scale = quantize_colwise(w, bits)
    if bits == 4:
        q = pack_int4(q)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32), bits=bits,
                           shape=tuple(w.shape))


# --------------------------------------------------------------------------
# QAT fake-quant with straight-through estimator (training-side integration).
# --------------------------------------------------------------------------
from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jax.Array, bits: int = 8) -> jax.Array:
    qmax = _qmax(bits)
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return (q * scale).astype(x.dtype)


def _fq_fwd(x, bits):
    return fake_quant(x, bits), None


def _fq_bwd(bits, _, g):
    return (g,)  # straight-through


fake_quant.defvjp(_fq_fwd, _fq_bwd)
