from repro.data.pipeline import SyntheticLMData, shard_batch
