"""Deterministic synthetic LM data pipeline.

Properties a production pipeline needs and this one has:

* **step-addressable determinism** — batch for step ``s`` is a pure function
  of ``(seed, s)``; restart-from-checkpoint replays the exact stream with no
  stored iterator state (the fault-tolerance contract in train/loop.py).
* **host-sharded feeding** — ``shard_batch`` device_puts each host's slice
  with the mesh sharding (single-process here, but the API matches
  ``jax.make_array_from_process_local_data``).
* **background prefetch** — a depth-2 thread prefetcher overlaps host data
  generation with device steps.

The token stream is a mixed Markov/zipf source so the LM loss has real
structure to learn (used by examples/train_100m.py to show loss descent).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class SyntheticLMData:
    def __init__(self, vocab_size: int, batch: int, seq: int, *,
                 seed: int = 0, embedding_dim: Optional[int] = None):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.embedding_dim = embedding_dim
        # fixed Markov backbone: each token prefers a successor band
        self._succ = np.random.default_rng(seed).integers(
            0, vocab_size, size=(min(vocab_size, 4096),), dtype=np.int64)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        noise = rng.random((self.batch, self.seq))
        jump = rng.integers(0, self.vocab, (self.batch, self.seq))
        for t in range(self.seq):
            follow = self._succ[toks[:, t] % len(self._succ)] % self.vocab
            toks[:, t + 1] = np.where(noise[:, t] < 0.75, follow, jump[:, t])
        out = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
        if self.embedding_dim:                       # frontend-stub archs
            emb = rng.standard_normal(
                (self.batch, self.seq, self.embedding_dim)).astype(np.float32)
            out["inputs"] = emb
        return out

    def __iter__(self) -> Iterator[dict]:
        s = 0
        while True:
            yield self.batch_at(s)
            s += 1


def shard_batch(batch: dict, mesh=None, specs: Optional[dict] = None) -> dict:
    """Device-put a host batch with mesh shardings (no-op mesh → local)."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    from jax.sharding import NamedSharding
    return {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
            for k, v in batch.items()}


class Prefetcher:
    """Depth-N background prefetch over a data iterator."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._t = threading.Thread(target=self._fill, daemon=True)
        self._t.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
