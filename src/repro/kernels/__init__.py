"""Pallas TPU kernels for CAMP compute hot-spots (+ jnp oracles in ref.py)."""
