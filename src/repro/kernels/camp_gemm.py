"""CAMP int8 GEMM Pallas TPU kernel.

This is the paper's `camp` instruction lifted to MXU granularity:

* paper: one instruction consumes a 4×16 A-panel (column-major) and a 16×4
  B-panel (row-major) and accumulates a 4×4 int32 tile in an auxiliary
  register, `kc/16` times, before one store.
* here: one grid step consumes a (bm×bk) A-block and a (bk×bn) B-block from
  VMEM and accumulates a (bm×bn) int32 tile in a VMEM scratch accumulator,
  K/bk times, before one store — with the **Cartesian scale epilogue**
  (outer product of per-row × per-column scales) fused into the flush.

The GotoBLAS blocking hierarchy of the paper (L3→L2→L1→registers) becomes
HBM→VMEM→VREG→MXU: ``BlockSpec`` index maps stream panels of A and B through
VMEM exactly like the 5-loop GotoBLAS schedule streams panels through caches,
and the int32 accumulator plays the auxiliary register. See
``repro.core.blocking`` for the block-size selection (the `kc/mc/nR` analogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _camp_gemm_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref):
    """One (i, j, k) grid step: acc += A_blk · B_blk; flush on the last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The CAMP outer-product-accumulate: int8 × int8 → int32 on the MXU.
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        # Cartesian (outer-product) scale epilogue: s_a ⊗ s_b.
        scale = sa_ref[...] * sb_ref[...]  # (bm,1)*(1,bn) -> (bm,bn)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def camp_gemm_i8(
    a_q: jax.Array,           # (M, K) int8
    b_q: jax.Array,           # (K, N) int8
    a_scale: jax.Array,       # (M, 1) f32
    b_scale: jax.Array,       # (1, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"camp_gemm_i8: ({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})")

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _camp_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(a_q, b_q, a_scale, b_scale)
