"""CAMP int8 GEMM Pallas TPU kernel.

This is the paper's `camp` instruction lifted to MXU granularity:

* paper: one instruction consumes a 4×16 A-panel (column-major) and a 16×4
  B-panel (row-major) and accumulates a 4×4 int32 tile in an auxiliary
  register, `kc/16` times, before one store.
* here: one grid step consumes a (bm×bk) A-block and a (bk×bn) B-block from
  VMEM and accumulates a (bm×bn) int32 tile in a VMEM scratch accumulator,
  K/bk times, before one store — with the **Cartesian scale epilogue**
  (outer product of per-row × per-column scales) fused into the flush.

The GotoBLAS blocking hierarchy of the paper (L3→L2→L1→registers) becomes
HBM→VMEM→VREG→MXU: ``BlockSpec`` index maps stream panels of A and B through
VMEM exactly like the 5-loop GotoBLAS schedule streams panels through caches,
and the int32 accumulator plays the auxiliary register. See
``repro.core.blocking`` for the block-size selection (the `kc/mc/nR` analogue)
and ``repro.core.autotune`` for the measured/modelled selection cache.

Two extensions over the bare paper kernel:

* ``epilogue=`` — elementwise tails (bias/silu/gelu/residual/mul, see
  :mod:`repro.kernels.epilogue`) applied to the f32 accumulator inside the
  flush, preserving the one-store property through bias-add and activations.
* arbitrary (M, N, K) — edge blocks are zero-padded to the block lattice and
  the result sliced back (:mod:`repro.kernels.padding`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogue import (epilogue_needs, flush_epilogue,
                                    parse_epilogue)
from repro.kernels.padding import pad_2d, round_up
from repro.kernels.pltpu_compat import CompilerParams


def _camp_gemm_kernel(*refs, stages, n_extra):
    """One (i, j, k) grid step: acc += A_blk · B_blk; flush on the last k."""
    a_ref, b_ref, sa_ref, sb_ref = refs[:4]
    extra = refs[4:4 + n_extra]
    o_ref, acc_ref = refs[4 + n_extra], refs[5 + n_extra]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The CAMP outer-product-accumulate: int8 × int8 → int32 on the MXU.
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        # Cartesian (outer-product) scale epilogue: s_a ⊗ s_b.
        flush_epilogue(acc_ref, sa_ref, sb_ref, o_ref, stages, extra)


def _epilogue_inputs(stages, bias, operand, *, n, bm, bn, mp, np_):
    """Pad the optional epilogue tensors; → (arrays, specs).

    Presence mismatches were already rejected by ``validate_epilogue`` at the
    dispatch layer; direct kernel callers get the same check here.
    """
    needs_bias, needs_opd = epilogue_needs(stages)
    if needs_bias != (bias is not None) or needs_opd != (operand is not None):
        raise ValueError(f"epilogue stages {stages} require bias={needs_bias},"
                         f" operand={needs_opd}")
    arrays, specs = [], []
    if needs_bias:
        arrays.append(pad_2d(bias.reshape(1, n), 1, np_))
        specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if needs_opd:
        arrays.append(pad_2d(operand, mp, np_))
        specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
    return arrays, specs


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "epilogue",
                     "interpret"),
)
def camp_gemm_i8(
    a_q: jax.Array,           # (M, K) int8
    b_q: jax.Array,           # (K, N) int8
    a_scale: jax.Array,       # (M, 1) f32
    b_scale: jax.Array,       # (1, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    epilogue: str = "none",
    bias: jax.Array | None = None,      # (N,) when 'bias' in epilogue
    operand: jax.Array | None = None,   # (M, N) when 'residual'/'mul'
    interpret: bool = False,
) -> jax.Array:
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    stages = parse_epilogue(epilogue)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)

    a_q = pad_2d(a_q, mp, kp)
    b_q = pad_2d(b_q, kp, np_)
    a_scale = pad_2d(a_scale, mp, 1, value=1.0)
    b_scale = pad_2d(b_scale, 1, np_, value=1.0)
    extra, extra_specs = _epilogue_inputs(stages, bias, operand, n=n, bm=bm,
                                          bn=bn, mp=mp, np_=np_)

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_camp_gemm_kernel, stages=stages, n_extra=len(extra)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(a_q, b_q, a_scale, b_scale, *extra)
    return out[:m, :n]
