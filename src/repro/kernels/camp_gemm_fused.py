"""Fused activation-quantize + CAMP GEMM Pallas TPU kernels.

The paper's CAMP pipeline quantizes the A-panel, runs the integer
outer-product accumulate, and applies the Cartesian scale — all inside one
hardware pipeline with a single store per accumulator lifetime. The seed port
broke that chain at the HBM level: ``quantize_rowwise`` ran as a separate
kernel (f32 activations read, int8 + scales written back to HBM, then re-read
by the GEMM). These kernels restore the paper's property at TPU granularity:

* the activation row-block arrives in VMEM in its storage dtype (bf16/f32),
* per-row absmax → scale → round/clip to int8 (or int4 range) happens on the
  VMEM-resident block — **the quantized activation tensor never exists in
  HBM**, and neither do its scales,
* the int32 accumulate and the scale/bias/activation epilogue run as before,
  with one store per (bm, bn) output tile.

Blocking: A uses a (bm, K) row-block whose index map is constant in (j, k),
so Pallas fetches each A row-panel from HBM exactly once per grid row and the
in-kernel K-loop slices sub-blocks out of VMEM (``pl.ds``). The per-row scale
is recomputed at k==0 of every (i, j) pass — a VPU reduction over a
VMEM-resident panel, free relative to the MXU work, and safe under Megacore
grid partitioning (no cross-j scratch dependence).

Bit-exactness: the in-kernel quantize is the same f32 expression chain as
``repro.kernels.ref.quantize_rowwise_ref``, so on block-divisible shapes the
fused w8a8 result is bit-identical to the unfused
``quantize_rowwise`` → ``camp_gemm_i8`` composition. K-padding preserves this
(zero columns don't move a row's absmax); padded M rows quantize to zeros and
are sliced away.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import INT4_QMAX, INT8_QMAX
from repro.kernels.camp_gemm import _epilogue_inputs
from repro.kernels.camp_gemm_w4 import _even_block_k, _unpack_k_rows
from repro.kernels.epilogue import flush_epilogue, parse_epilogue
from repro.kernels.padding import pad_2d, round_up
from repro.kernels.pltpu_compat import CompilerParams


def _fused_kernel(*refs, stages, n_extra, bk, qmax, unpack_b):
    x_ref, b_ref, sb_ref = refs[:3]
    extra = refs[3:3 + n_extra]
    o_ref = refs[3 + n_extra]
    acc_ref, sa_ref = refs[4 + n_extra], refs[5 + n_extra]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # Per-row absmax over the whole K row — the A-panel "pack" step of the
        # paper, done on the VMEM-resident panel. Same f32 expression chain as
        # quantize_rowwise_ref, so quantized values are bit-identical.
        x32 = x_ref[...].astype(jnp.float32)
        absmax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
        sa_ref[...] = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x_blk = x_ref[:, pl.ds(k * bk, bk)].astype(jnp.float32)
    a_q = jnp.clip(jnp.round(x_blk / sa_ref[...]), -qmax, qmax).astype(jnp.int8)
    b = b_ref[...]
    if unpack_b:
        b = _unpack_k_rows(b)  # VMEM-resident nibble unpack
    acc_ref[...] += jax.lax.dot_general(
        a_q, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        flush_epilogue(acc_ref, sa_ref, sb_ref, o_ref, stages, extra)


def _camp_gemm_fused(x, b, b_scale, *, a_bits, w_bits, block_m, block_n,
                     block_k, out_dtype, epilogue, bias, operand, interpret):
    m, k = x.shape
    if w_bits == 8:
        kb, n = b.shape
        assert k == kb, (x.shape, b.shape)
    else:
        kb, n = b.shape
        assert k == 2 * kb, (x.shape, b.shape)
    stages = parse_epilogue(epilogue)
    qmax = INT8_QMAX if a_bits == 8 else INT4_QMAX

    bm, bn = min(block_m, m), min(block_n, n)
    bk = _even_block_k(block_k, k) if w_bits == 4 else min(block_k, k)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)

    x = pad_2d(x, mp, kp)
    if w_bits == 8:
        b = pad_2d(b, kp, np_)
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    else:
        b = pad_2d(b, kp // 2, np_)
        b_spec = pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j))
    b_scale = pad_2d(b_scale, 1, np_, value=1.0)
    extra, extra_specs = _epilogue_inputs(stages, bias, operand, n=n, bm=bm,
                                          bn=bn, mp=mp, np_=np_)

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, stages=stages, n_extra=len(extra),
                          bk=bk, qmax=qmax, unpack_b=(w_bits == 4)),
        grid=grid,
        in_specs=[
            # Whole padded K row per A block: constant in (j, k) → one HBM
            # fetch per grid row, K-loop slices from VMEM.
            pl.BlockSpec((bm, kp), lambda i, j, kk: (i, 0)),
            b_spec,
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),   # accumulator
            pltpu.VMEM((bm, 1), jnp.float32),  # per-row activation scales
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(x, b, b_scale, *extra)
    return out[:m, :n]


_FUSED_STATICS = ("block_m", "block_n", "block_k", "out_dtype", "epilogue",
                  "interpret")


@functools.partial(jax.jit, static_argnames=_FUSED_STATICS)
def camp_gemm_fused_w8a8(
    x: jax.Array,          # (M, K) f32/bf16 activations — quantized in VMEM
    b_q: jax.Array,        # (K, N) int8
    b_scale: jax.Array,    # (1, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    epilogue: str = "none",
    bias: jax.Array | None = None,
    operand: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _camp_gemm_fused(x, b_q, b_scale, a_bits=8, w_bits=8,
                            block_m=block_m, block_n=block_n, block_k=block_k,
                            out_dtype=out_dtype, epilogue=epilogue, bias=bias,
                            operand=operand, interpret=interpret)


@functools.partial(jax.jit, static_argnames=_FUSED_STATICS)
def camp_gemm_fused_w4a8(
    x: jax.Array,          # (M, K) f32/bf16
    b_packed: jax.Array,   # (K//2, N) int8 packed int4 weights
    b_scale: jax.Array,    # (1, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    epilogue: str = "none",
    bias: jax.Array | None = None,
    operand: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _camp_gemm_fused(x, b_packed, b_scale, a_bits=8, w_bits=4,
                            block_m=block_m, block_n=block_n, block_k=block_k,
                            out_dtype=out_dtype, epilogue=epilogue, bias=bias,
                            operand=operand, interpret=interpret)


@functools.partial(jax.jit, static_argnames=_FUSED_STATICS)
def camp_gemm_fused_w4a4(
    x: jax.Array,          # (M, K) f32/bf16 — quantized to the int4 range
    b_packed: jax.Array,   # (K//2, N) int8 packed int4 weights
    b_scale: jax.Array,    # (1, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    epilogue: str = "none",
    bias: jax.Array | None = None,
    operand: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _camp_gemm_fused(x, b_packed, b_scale, a_bits=4, w_bits=4,
                            block_m=block_m, block_n=block_n, block_k=block_k,
                            out_dtype=out_dtype, epilogue=epilogue, bias=bias,
                            operand=operand, interpret=interpret)
