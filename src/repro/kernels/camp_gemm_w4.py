"""CAMP packed-int4 GEMM Pallas TPU kernels (a8w4 and a4w4).

The paper's key int4 result is that the hybrid multiplier runs 4-bit GEMMs at
2× the int8 rate with *zero* pack/unpack instruction overhead. The TPU-native
statement of the same idea: int4 weights are stored **2-per-byte in HBM**
(halving the memory-roofline term, which is what actually bounds inference
decode), and the nibble unpack happens *inside* the kernel on VMEM-resident
blocks where it is free relative to the HBM stream it eliminated.

Layouts (see repro.core.quant):
  * weights  (K, N) int4 → packed (K//2, N) int8, low nibble = even k.
  * activations for a4w4: (M, K) int4 → packed (M, K//2) int8 along K.

Like :mod:`repro.kernels.camp_gemm`, both kernels support fused ``epilogue=``
tails on the f32 accumulator and arbitrary (M, N, K) via edge-block padding
(K is padded on the *packed* axis, two zero nibbles per padded byte).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.camp_gemm import _epilogue_inputs
from repro.kernels.epilogue import flush_epilogue, parse_epilogue
from repro.kernels.padding import pad_2d, round_up
from repro.kernels.pltpu_compat import CompilerParams


def _unpack_k_rows(packed):
    """(bk//2, bn) int8 → (bk, bn) int4-valued int8, sign-extended nibbles."""
    lo = ((packed << 4).astype(jnp.int8) >> 4).astype(jnp.int8)
    hi = (packed >> 4).astype(jnp.int8)
    # Interleave rows: out[2i] = lo[i], out[2i+1] = hi[i].
    bk2, bn = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * bk2, bn)


def _unpack_k_cols(packed):
    """(bm, bk//2) int8 → (bm, bk): out[:, 2i] = lo, out[:, 2i+1] = hi."""
    lo = ((packed << 4).astype(jnp.int8) >> 4).astype(jnp.int8)
    hi = (packed >> 4).astype(jnp.int8)
    bm, bk2 = packed.shape
    return jnp.stack([lo, hi], axis=2).reshape(bm, 2 * bk2)


def _camp_gemm_w4_kernel(*refs, stages, n_extra):
    a_ref, b_ref, sa_ref, sb_ref = refs[:4]
    extra = refs[4:4 + n_extra]
    o_ref, acc_ref = refs[4 + n_extra], refs[5 + n_extra]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b_q = _unpack_k_rows(b_ref[...])  # VMEM-resident unpack: no HBM cost
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        flush_epilogue(acc_ref, sa_ref, sb_ref, o_ref, stages, extra)


def _camp_gemm_a4w4_kernel(*refs, stages, n_extra):
    a_ref, b_ref, sa_ref, sb_ref = refs[:4]
    extra = refs[4:4 + n_extra]
    o_ref, acc_ref = refs[4 + n_extra], refs[5 + n_extra]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_q = _unpack_k_cols(a_ref[...])
    b_q = _unpack_k_rows(b_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        a_q, b_q, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        flush_epilogue(acc_ref, sa_ref, sb_ref, o_ref, stages, extra)


def _even_block_k(block_k: int, k: int) -> int:
    """bk for a packed-K kernel: ≤ k, even (one packed byte = two k's)."""
    bk = min(block_k, k)
    return max(2, bk - (bk % 2))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "epilogue",
                     "interpret"),
)
def camp_gemm_w4(
    a_q: jax.Array,        # (M, K) int8 activations
    b_packed: jax.Array,   # (K//2, N) int8 packed int4 weights
    a_scale: jax.Array,    # (M, 1) f32
    b_scale: jax.Array,    # (1, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    epilogue: str = "none",
    bias: jax.Array | None = None,
    operand: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    m, k = a_q.shape
    kp_rows, n = b_packed.shape
    assert k == 2 * kp_rows, (a_q.shape, b_packed.shape)
    stages = parse_epilogue(epilogue)
    bm, bn = min(block_m, m), min(block_n, n)
    bk = _even_block_k(block_k, k)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)

    a_q = pad_2d(a_q, mp, kp)
    b_packed = pad_2d(b_packed, kp // 2, np_)
    a_scale = pad_2d(a_scale, mp, 1, value=1.0)
    b_scale = pad_2d(b_scale, 1, np_, value=1.0)
    extra, extra_specs = _epilogue_inputs(stages, bias, operand, n=n, bm=bm,
                                          bn=bn, mp=mp, np_=np_)

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_camp_gemm_w4_kernel, stages=stages,
                          n_extra=len(extra)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(a_q, b_packed, a_scale, b_scale, *extra)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "epilogue",
                     "interpret"),
)
def camp_gemm_a4w4(
    a_packed: jax.Array,   # (M, K//2) int8 packed int4 activations
    b_packed: jax.Array,   # (K//2, N) int8 packed int4 weights
    a_scale: jax.Array,    # (M, 1) f32
    b_scale: jax.Array,    # (1, N) f32
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    epilogue: str = "none",
    bias: jax.Array | None = None,
    operand: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    m, kp_rows = a_packed.shape
    kp_rows2, n = b_packed.shape
    assert kp_rows == kp_rows2, (a_packed.shape, b_packed.shape)
    k = 2 * kp_rows
    stages = parse_epilogue(epilogue)
    bm, bn = min(block_m, m), min(block_n, n)
    bk = _even_block_k(block_k, k)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)

    a_packed = pad_2d(a_packed, mp, kp // 2)
    b_packed = pad_2d(b_packed, kp // 2, np_)
    a_scale = pad_2d(a_scale, mp, 1, value=1.0)
    b_scale = pad_2d(b_scale, 1, np_, value=1.0)
    extra, extra_specs = _epilogue_inputs(stages, bias, operand, n=n, bm=bm,
                                          bn=bn, mp=mp, np_=np_)

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_camp_gemm_a4w4_kernel, stages=stages,
                          n_extra=len(extra)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk // 2), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(a_packed, b_packed, a_scale, b_scale, *extra)
    return out[:m, :n]
