"""Fused GEMM epilogues: elementwise tails applied to the f32 accumulator.

The CAMP pipeline's whole point is "one store per accumulator lifetime": the
int32 tile lives in a VMEM scratch across the K loop and is written to HBM
exactly once, already scaled. Any elementwise op that runs *after* the GEMM as
a standalone XLA kernel re-reads and re-writes the (M, N) output through HBM —
for a decode-shaped GEMM that round-trip costs more than the matmul itself.
These epilogue stages run on the f32 accumulator inside the kernel flush,
*before* the single downcast store, so bias/activation/residual-gating never
touch HBM.

An epilogue is a ``+``-separated stage string applied left to right:

  ==========  ======================================  =================
  stage       effect on the f32 accumulator ``y``     extra tensor
  ==========  ======================================  =================
  ``bias``      ``y + bias``  (broadcast over rows)   ``bias`` (N,)
  ``silu``      ``silu(y)``                           —
  ``gelu``      ``gelu(y)`` (tanh approximation)      —
  ``residual``  ``y + operand``                       ``operand`` (M, N)
  ``mul``       ``y * operand``                       ``operand`` (M, N)
  ==========  ======================================  =================

e.g. ``"bias+silu"`` for a biased SiLU projection, ``"mul"`` with the
pre-activated gate as ``operand`` for the up-projection of a gated MLP.
``apply_epilogue`` is pure jnp so the exact same function serves as the Pallas
in-kernel implementation, the fused-XLA fallback, and the test oracle.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

EPILOGUE_STAGES = ("bias", "silu", "gelu", "residual", "mul")


def parse_epilogue(epilogue: Optional[str]) -> Tuple[str, ...]:
    """'bias+silu' → ('bias', 'silu'); None/'none'/'' → ()."""
    if not epilogue or epilogue == "none":
        return ()
    stages = tuple(s.strip() for s in epilogue.split("+") if s.strip())
    for s in stages:
        if s not in EPILOGUE_STAGES:
            raise ValueError(f"unknown epilogue stage {s!r}; valid: {EPILOGUE_STAGES}")
    if stages.count("bias") > 1:
        raise ValueError(f"epilogue {epilogue!r}: 'bias' may appear at most once")
    if stages.count("residual") + stages.count("mul") > 1:
        raise ValueError(
            f"epilogue {epilogue!r}: at most one operand stage (residual|mul)")
    return stages


def epilogue_needs(stages: Sequence[str]) -> Tuple[bool, bool]:
    """→ (needs_bias, needs_operand)."""
    return "bias" in stages, ("residual" in stages or "mul" in stages)


def apply_epilogue(y: jax.Array, stages: Sequence[str], *, bias=None,
                   operand=None) -> jax.Array:
    """Apply ``stages`` to the f32 accumulator ``y`` (shape (bm, bn)).

    ``bias``: (1, bn); ``operand``: (bm, bn). Both are upcast to f32 here so
    callers can stream them in their storage dtype.
    """
    for s in stages:
        if s == "bias":
            y = y + bias.astype(jnp.float32)
        elif s == "silu":
            y = y * jax.nn.sigmoid(y)
        elif s == "gelu":
            y = jax.nn.gelu(y, approximate=True)
        elif s == "residual":
            y = y + operand.astype(jnp.float32)
        else:  # mul
            y = y * operand.astype(jnp.float32)
    return y


def validate_epilogue(epilogue: Optional[str], bias, operand) -> Tuple[str, ...]:
    """Parse ``epilogue`` and require bias/operand presence to match it.

    Called at every dispatch entry point (all impls), so forgetting
    ``epilogue='bias'`` while passing ``bias=`` fails loudly everywhere, not
    just on the pallas path.
    """
    stages = parse_epilogue(epilogue)
    needs_bias, needs_opd = epilogue_needs(stages)
    if needs_bias != (bias is not None):
        raise ValueError(
            f"epilogue {epilogue!r} {'requires' if needs_bias else 'takes no'}"
            f" bias= (got bias={'set' if bias is not None else 'None'})")
    if needs_opd != (operand is not None):
        raise ValueError(
            f"epilogue {epilogue!r} {'requires' if needs_opd else 'takes no'}"
            f" operand= (got operand={'set' if operand is not None else 'None'})")
    return stages


def split_extra_refs(stages: Sequence[str], extra: Sequence):
    """Name the optional trailing (bias, operand) kernel refs/arrays."""
    needs_bias, needs_opd = epilogue_needs(stages)
    i = 0
    bias = opd = None
    if needs_bias:
        bias = extra[i]
        i += 1
    if needs_opd:
        opd = extra[i]
        i += 1
    assert i == len(extra), (stages, len(extra))
    return bias, opd


def flush_epilogue(acc_ref, sa_ref, sb_ref, o_ref, stages, extra) -> None:
    """The shared kernel flush: Cartesian scale → epilogue stages → one
    downcast store. Every CAMP kernel (unfused, w4, fused) must flush through
    this exact expression chain — the ref-oracle bit-exactness tests assume
    all five kernels agree on it.
    """
    scale = sa_ref[...] * sb_ref[...]  # (bm,1)*(1,bn) -> (bm,bn)
    y = acc_ref[...].astype(jnp.float32) * scale
    bias_ref, opd_ref = split_extra_refs(stages, extra)
    y = apply_epilogue(y, stages,
                       bias=None if bias_ref is None else bias_ref[...],
                       operand=None if opd_ref is None else opd_ref[...])
    o_ref[...] = y.astype(o_ref.dtype)
