"""Flash-attention Pallas TPU kernel (causal, online-softmax).

The prefill roofline is dominated by score traffic: XLA's chunked attention
writes/reads (bq × S) f32 score blocks through HBM every layer. This kernel
keeps scores, softmax statistics and the output accumulator in VMEM — HBM
traffic collapses to Q+K+V+O exactly once, which is what moves the
memory-roofline term for the 32k prefill cells (EXPERIMENTS §Perf).

Layout: inputs (BH, S, D) — batch×heads folded. Grid (BH, Sq/bq, Skv/bk),
kv innermost ('arbitrary'), carrying running (m, l, acc) scratch per q-block.
Causal blocks strictly above the diagonal are skipped via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, bq: int, bk: int, causal: bool):
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: the whole kv-block is masked when its first row starts past the
    # last q row → skip (saves ~half the passes).
    run = (not causal) or (j * bk <= i * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0]                                 # (bq, d)
        k = k_ref[0]                                 # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qi >= kj, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, D) (same S; GQA callers repeat/fold kv heads).

    Returns (BH, S, D) in q.dtype.
    """
    bh, s, d = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    while s % bq:
        bq //= 2
    while s % bk:
        bk //= 2
    scale = d ** -0.5
    grid = (bh, s // bq, s // bk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(q, k, v)
