"""Jit'd wrappers with implementation dispatch for the CAMP kernels.

Every op exposes ``impl``:

* ``'pallas'`` — the Pallas TPU kernel (``interpret=True`` automatically when
  running on the CPU backend, which is how this container validates them).
* ``'xla'``    — plain XLA int8 ``dot_general`` + scale epilogue. This is what
  the multi-pod dry-run lowers (the CPU backend cannot compile Mosaic), and on
  TPU it is also the fallback XLA would fuse itself.
* ``'hybrid'`` — the paper's §3 hybrid-multiplier decomposition (int8 GEMM as
  four int4-range GEMMs). Bit-exact with 'xla'; exists as the algebraic
  witness of the hardware design.
* ``'ref'``    — the pure-jnp oracle from :mod:`repro.kernels.ref`.

``impl='auto'`` picks 'pallas' on TPU and 'xla' elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hybrid as _hybrid
from repro.kernels import ref as _ref
from repro.kernels.camp_gemm import camp_gemm_i8 as _pallas_i8
from repro.kernels.camp_gemm_w4 import camp_gemm_a4w4 as _pallas_a4w4
from repro.kernels.camp_gemm_w4 import camp_gemm_w4 as _pallas_w4
from repro.kernels.quantize import quantize_rowwise_kernel as _pallas_quant

_VALID = ("auto", "pallas", "xla", "hybrid", "ref")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl not in _VALID:
        raise ValueError(f"impl={impl!r} not in {_VALID}")
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def gemm_i8(a_q, b_q, a_scale, b_scale, *, out_dtype=jnp.float32,
            impl: str = "auto", block=(256, 256, 512)):
    """CAMP int8 GEMM: (M,K)i8 × (K,N)i8 → (M,N)out_dtype with scale epilogue."""
    impl = _resolve(impl)
    if impl == "pallas":
        bm, bn, bk = block
        return _pallas_i8(a_q, b_q, a_scale, b_scale, block_m=bm, block_n=bn,
                          block_k=bk, out_dtype=out_dtype, interpret=not _on_tpu())
    if impl == "hybrid":
        acc = _hybrid.hybrid_matmul_i8(a_q, b_q)
        return (acc.astype(jnp.float32) * (a_scale * b_scale)).astype(out_dtype)
    if impl == "ref":
        return _ref.gemm_i8_ref(a_q, b_q, a_scale, b_scale, out_dtype)
    # 'xla'
    acc = _ref.dot_i32(a_q, b_q)
    return (acc.astype(jnp.float32) * (a_scale * b_scale)).astype(out_dtype)


def gemm_w4(a_q, b_packed, a_scale, b_scale, *, out_dtype=jnp.float32,
            impl: str = "auto", block=(256, 256, 512)):
    """CAMP a8w4 GEMM: int8 activations × packed-int4 weights."""
    impl = _resolve(impl)
    if impl == "pallas":
        bm, bn, bk = block
        return _pallas_w4(a_q, b_packed, a_scale, b_scale, block_m=bm, block_n=bn,
                          block_k=bk, out_dtype=out_dtype, interpret=not _on_tpu())
    if impl == "hybrid":
        from repro.core.quant import unpack_int4
        b_q = unpack_int4(b_packed, a_q.shape[-1])
        acc = _hybrid.hybrid_matmul_w4a8(a_q, b_q)
        return (acc.astype(jnp.float32) * (a_scale * b_scale)).astype(out_dtype)
    if impl == "ref":
        return _ref.gemm_w4_ref(a_q, b_packed, a_scale, b_scale, out_dtype)
    # 'xla': unpack outside the (nonexistent) kernel, then int8 dot.
    from repro.core.quant import unpack_int4
    b_q = unpack_int4(b_packed, a_q.shape[-1])
    acc = _ref.dot_i32(a_q, b_q)
    return (acc.astype(jnp.float32) * (a_scale * b_scale)).astype(out_dtype)


def gemm_a4w4(a_packed, b_packed, k, a_scale, b_scale, *, out_dtype=jnp.float32,
              impl: str = "auto", block=(256, 256, 512)):
    """CAMP int4 GEMM: both operands packed 2-per-byte along K (logical K=k)."""
    impl = _resolve(impl)
    if impl == "pallas":
        bm, bn, bk = block
        return _pallas_a4w4(a_packed, b_packed, a_scale, b_scale, block_m=bm,
                            block_n=bn, block_k=bk, out_dtype=out_dtype,
                            interpret=not _on_tpu())
    return _ref.gemm_a4w4_ref(a_packed, b_packed, k, a_scale, b_scale, out_dtype)


def quantize_rowwise(x, *, bits: int = 8, impl: str = "auto", block_m: int = 256):
    """Fused dynamic rowwise quantization: x → (int8 q, f32 scale (M,1))."""
    impl = _resolve(impl)
    if impl == "pallas":
        return _pallas_quant(x, bits=bits, block_m=block_m, interpret=not _on_tpu())
    return _ref.quantize_rowwise_ref(x, bits)
