"""Jit'd wrappers with implementation dispatch for the CAMP kernels.

Every op exposes ``impl``:

* ``'pallas'`` — the Pallas TPU kernel (``interpret=True`` automatically when
  running on the CPU backend, which is how this container validates them).
* ``'xla'``    — plain XLA int8 ``dot_general`` + scale epilogue. This is what
  the multi-pod dry-run lowers (the CPU backend cannot compile Mosaic), and on
  TPU it is also the fallback XLA would fuse itself.
* ``'hybrid'`` — the paper's §3 hybrid-multiplier decomposition (int8 GEMM as
  four int4-range GEMMs). Bit-exact with 'xla'; exists as the algebraic
  witness of the hardware design.
* ``'ref'``    — the pure-jnp oracle from :mod:`repro.kernels.ref`.

``impl='auto'`` picks 'pallas' on TPU and 'xla' elsewhere.

Common extensions across the GEMM ops:

* ``epilogue=`` / ``bias=`` / ``operand=`` — fused elementwise tails on the
  f32 accumulator (see :mod:`repro.kernels.epilogue`); non-pallas impls apply
  the identical jnp expression after the scale so every impl stays an oracle
  for every other.
* ``block=None`` (the default) — block sizes come from the
  :mod:`repro.core.autotune` cache (seeded by ``choose_blocks``) instead of a
  hardcoded triple.

The ``gemm_*_fused`` family additionally fuses the dynamic activation
quantization *into* the GEMM: callers hand over bf16/f32 activations and the
int8/int4 payload + scales never exist in HBM
(:mod:`repro.kernels.camp_gemm_fused`). Non-pallas impls become a single
jitted quantize→dot→epilogue graph, which XLA fuses — the same HBM-traffic
shape, expressed at the XLA level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core import hybrid as _hybrid
from repro.core.quant import unpack_int4
from repro.kernels import ref as _ref
from repro.kernels.camp_gemm import camp_gemm_i8 as _pallas_i8
from repro.kernels.camp_gemm_fused import camp_gemm_fused_w4a4 as _pallas_f_a4w4
from repro.kernels.camp_gemm_fused import camp_gemm_fused_w4a8 as _pallas_f_w4
from repro.kernels.camp_gemm_fused import camp_gemm_fused_w8a8 as _pallas_f_i8
from repro.kernels.camp_gemm_w4 import camp_gemm_a4w4 as _pallas_a4w4
from repro.kernels.camp_gemm_w4 import camp_gemm_w4 as _pallas_w4
from repro.kernels.epilogue import (apply_epilogue, parse_epilogue,
                                    validate_epilogue)
from repro.kernels.quantize import quantize_rowwise_kernel as _pallas_quant

_VALID = ("auto", "pallas", "xla", "hybrid", "ref")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl not in _VALID:
        raise ValueError(f"impl={impl!r} not in {_VALID}")
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def _blocks(kind, m, n, k, block, *, fused=False, a_in_bytes=4):
    """Explicit block triple, or the autotune cache's pick for this shape."""
    if block is not None:
        return block
    return autotune.get_blocks(kind, m, n, k, fused=fused,
                               a_in_bytes=a_in_bytes)


def _tail(y32, epilogue, bias, operand, out_dtype):
    """Non-pallas epilogue: identical jnp expression to the kernels' flush."""
    y32 = apply_epilogue(y32, parse_epilogue(epilogue),
                         bias=None if bias is None else bias.reshape(1, -1),
                         operand=operand)
    return y32.astype(out_dtype)


def gemm_i8(a_q, b_q, a_scale, b_scale, *, out_dtype=jnp.float32,
            impl: str = "auto", block=None, epilogue: str = "none",
            bias=None, operand=None):
    """CAMP int8 GEMM: (M,K)i8 × (K,N)i8 → (M,N)out_dtype with scale epilogue."""
    impl = _resolve(impl)
    validate_epilogue(epilogue, bias, operand)
    (m, k), n = a_q.shape, b_q.shape[1]
    if impl == "pallas":
        bm, bn, bk = _blocks("i8", m, n, k, block)
        return _pallas_i8(a_q, b_q, a_scale, b_scale, block_m=bm, block_n=bn,
                          block_k=bk, out_dtype=out_dtype, epilogue=epilogue,
                          bias=bias, operand=operand, interpret=not _on_tpu())
    if impl == "hybrid":
        acc = _hybrid.hybrid_matmul_i8(a_q, b_q)
    else:  # 'xla' / 'ref'
        acc = _ref.dot_i32(a_q, b_q)
    return _tail(acc.astype(jnp.float32) * (a_scale * b_scale), epilogue,
                 bias, operand, out_dtype)


def gemm_w4(a_q, b_packed, a_scale, b_scale, *, out_dtype=jnp.float32,
            impl: str = "auto", block=None, epilogue: str = "none",
            bias=None, operand=None):
    """CAMP a8w4 GEMM: int8 activations × packed-int4 weights."""
    impl = _resolve(impl)
    validate_epilogue(epilogue, bias, operand)
    (m, k), n = a_q.shape, b_packed.shape[1]
    if impl == "pallas":
        bm, bn, bk = _blocks("w4", m, n, k, block)
        return _pallas_w4(a_q, b_packed, a_scale, b_scale, block_m=bm,
                          block_n=bn, block_k=bk, out_dtype=out_dtype,
                          epilogue=epilogue, bias=bias, operand=operand,
                          interpret=not _on_tpu())
    b_q = unpack_int4(b_packed, k)
    if impl == "hybrid":
        acc = _hybrid.hybrid_matmul_w4a8(a_q, b_q)
    else:  # 'xla' / 'ref': unpack outside the (nonexistent) kernel, int8 dot
        acc = _ref.dot_i32(a_q, b_q)
    return _tail(acc.astype(jnp.float32) * (a_scale * b_scale), epilogue,
                 bias, operand, out_dtype)


def gemm_a4w4(a_packed, b_packed, k, a_scale, b_scale, *,
              out_dtype=jnp.float32, impl: str = "auto", block=None,
              epilogue: str = "none", bias=None, operand=None):
    """CAMP int4 GEMM: both operands packed 2-per-byte along K (logical K=k)."""
    impl = _resolve(impl)
    validate_epilogue(epilogue, bias, operand)
    m, n = a_packed.shape[0], b_packed.shape[1]
    if impl == "pallas":
        bm, bn, bk = _blocks("a4w4", m, n, k, block)
        return _pallas_a4w4(a_packed, b_packed, a_scale, b_scale, block_m=bm,
                            block_n=bn, block_k=bk, out_dtype=out_dtype,
                            epilogue=epilogue, bias=bias, operand=operand,
                            interpret=not _on_tpu())
    a_q = unpack_int4(a_packed.T, k).T
    b_q = unpack_int4(b_packed, k)
    acc = _ref.dot_i32(a_q, b_q)
    return _tail(acc.astype(jnp.float32) * (a_scale * b_scale), epilogue,
                 bias, operand, out_dtype)


def quantize_rowwise(x, *, bits: int = 8, impl: str = "auto", block_m: int = 256):
    """Fused dynamic rowwise quantization: x → (int8 q, f32 scale (M,1))."""
    impl = _resolve(impl)
    if impl == "pallas":
        return _pallas_quant(x, bits=bits, block_m=block_m, interpret=not _on_tpu())
    return _ref.quantize_rowwise_ref(x, bits)


# ---------------------------------------------------------------------------
# Fused activation-quantize + GEMM (+ epilogue): one kernel, one store.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("a_bits", "w4", "hybrid", "out_dtype",
                                    "epilogue"))
def _fused_fallback(x, b, b_scale, bias, operand, *, a_bits, w4, hybrid,
                    out_dtype, epilogue):
    """Single jitted quantize→dot→epilogue graph (XLA fuses the chain).

    ``hybrid=True`` swaps the int32 dot for the paper's §3 hybrid-multiplier
    decomposition so ``impl='hybrid'`` keeps its meaning on the fused path.
    """
    if w4:
        b = unpack_int4(b, x.shape[-1])
    a_q, a_s = _ref.quantize_rowwise_ref(x, a_bits)
    if hybrid:
        acc = (_hybrid.hybrid_matmul_w4a8(a_q, b) if w4
               else _hybrid.hybrid_matmul_i8(a_q, b))
    else:
        acc = _ref.dot_i32(a_q, b)
    return _tail(acc.astype(jnp.float32) * (a_s * b_scale), epilogue, bias,
                 operand, out_dtype)


def _gemm_fused(kind, x, b, b_scale, *, out_dtype, impl, block, epilogue,
                bias, operand):
    impl = _resolve(impl)
    validate_epilogue(epilogue, bias, operand)
    (m, k), n = x.shape, b.shape[1]
    if impl == "pallas":
        bm, bn, bk = _blocks(kind, m, n, k, block, fused=True,
                             a_in_bytes=x.dtype.itemsize)
        fn = {"i8": _pallas_f_i8, "w4": _pallas_f_w4, "a4w4": _pallas_f_a4w4}[kind]
        return fn(x, b, b_scale, block_m=bm, block_n=bn, block_k=bk,
                  out_dtype=out_dtype, epilogue=epilogue, bias=bias,
                  operand=operand, interpret=not _on_tpu())
    # a4w4 has no hybrid decomposition (matches the unfused dispatch, which
    # routes every non-pallas a4w4 impl through the ref dot).
    return _fused_fallback(x, b, b_scale, bias, operand,
                           a_bits=(4 if kind == "a4w4" else 8),
                           w4=(kind != "i8"),
                           hybrid=(impl == "hybrid" and kind != "a4w4"),
                           out_dtype=out_dtype, epilogue=epilogue)


def gemm_i8_fused(x, b_q, b_scale, *, out_dtype=jnp.float32,
                  impl: str = "auto", block=None, epilogue: str = "none",
                  bias=None, operand=None):
    """w8a8 with in-kernel activation quantization: (M,K)f × (K,N)i8."""
    return _gemm_fused("i8", x, b_q, b_scale, out_dtype=out_dtype, impl=impl,
                       block=block, epilogue=epilogue, bias=bias,
                       operand=operand)


def gemm_w4_fused(x, b_packed, b_scale, *, out_dtype=jnp.float32,
                  impl: str = "auto", block=None, epilogue: str = "none",
                  bias=None, operand=None):
    """w4a8 with in-kernel activation quantization: (M,K)f × (K//2,N)packed."""
    return _gemm_fused("w4", x, b_packed, b_scale, out_dtype=out_dtype,
                       impl=impl, block=block, epilogue=epilogue, bias=bias,
                       operand=operand)


def gemm_a4w4_fused(x, b_packed, b_scale, *, out_dtype=jnp.float32,
                    impl: str = "auto", block=None, epilogue: str = "none",
                    bias=None, operand=None):
    """w4a4 with in-kernel int4 activation quantization — the packed int4
    activation tensor of the unfused path never exists at all."""
    return _gemm_fused("a4w4", x, b_packed, b_scale, out_dtype=out_dtype,
                       impl=impl, block=block, epilogue=epilogue, bias=bias,
                       operand=operand)
