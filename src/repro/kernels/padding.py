"""Edge-block padding for the CAMP kernels.

Pallas TPU grids want every dimension to be a whole number of blocks; real
serving shapes (ragged batch rows, odd vocab slices, 1-token decode) are not.
Rather than masking inside every kernel, the wrappers pad operands up to the
block lattice in HBM-side jnp (XLA fuses the pad into the producing op) and
slice the result back. Zero padding is semantically inert everywhere in the
CAMP pipeline:

* GEMM: zero rows/cols of A/B contribute nothing to the int32 accumulator.
* rowwise quantization: extra zero K-columns do not change a row's absmax,
  so quantized values — and therefore the fused kernels' in-VMEM scales —
  are bit-identical to the unpadded computation.
* scales are padded with 1.0 (not 0.0) so padded lanes stay finite.

Padded output rows/cols are garbage by construction and are sliced away
before returning.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def pad_2d(x: jax.Array, rows: int, cols: int, value=0) -> jax.Array:
    """Pad a 2-D array up to (rows, cols) with ``value`` (no-op when equal)."""
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)), constant_values=value)
