"""Paged single-token decode attention: block-table gather + in-register
int8 dequant + online softmax, all in VMEM.

Decode attention is the serving roofline's dominant term: each new token
re-reads the whole KV cache. With a paged int8 cache the HBM traffic per
step collapses to the pages a sequence actually occupies (not the
``(B, max_len)`` slab) at one byte per element — and this kernel never
materializes an f32 copy of the cache in HBM: pages are gathered via the
block table with scalar-prefetch BlockSpec index maps, dequantized
**in-register** with their per-token scales, and consumed by an online-softmax
accumulator held in VMEM scratch.

Layout: q (B, KV, G, hd) — one token per sequence, GQA groups folded per
kv head. Pages (P, KV, page_size, hd); scales (P, KV, page_size) — one
scale per (page, head, token) row, so stored bytes are write-once and
independent of how tokens were batched into the page (single appends vs
speculative verify panels). Block table (B, max_pages) int32; lengths (B,)
int32. Grid (B, KV, max_pages), pages innermost ('arbitrary') carrying
running (m, l, acc) scratch. Pages past a sequence's length are skipped
via ``pl.when`` (padded block-table slots are never touched because the
skip test uses lengths, not the table).

``impl='auto'`` follows the repo convention: Pallas on TPU, the XLA
reference elsewhere. The Pallas path requires int8 pages with scales; float
pages (used by the bf16 paged pool) route through the reference.

Tensor parallelism: :func:`paged_attention_tp` shard_maps the kernel over a
mesh's ``model`` axis with every KV-head-carrying operand split by head —
each device gathers/dequantizes/attends only its local heads of its local
page shards, so the KV hot path moves **zero** bytes between devices (the
one collective of a TP decode layer is the row-parallel ``wo`` all-reduce
that follows).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.pltpu_compat import CompilerParams

_NEG = -1e30
_VALID = ("auto", "pallas", "xla")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl not in _VALID:
        raise ValueError(f"impl={impl!r} not in {_VALID}")
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


# ---------------------------------------------------------------------------
# XLA reference (oracle for the kernel; the non-TPU serving path)
# ---------------------------------------------------------------------------
def paged_attention_reference(q, k_pages, v_pages, k_scale, v_scale, tables,
                              lengths, *, sm_scale: Optional[float] = None):
    """Gather → dequantize → masked softmax, as one jnp expression.

    q: (B, KV, G, hd); pages (P, KV, ps, hd); scales (P, KV, ps) per-token
    or None; tables (B, max_pages) int32; lengths (B,) int32. Returns
    (B, KV, G, hd).
    """
    b, kv, g, hd = q.shape
    ps = k_pages.shape[2]
    max_pages = tables.shape[1]
    scale = sm_scale if sm_scale is not None else hd ** -0.5

    def gather(pages, scales):
        x = jnp.take(pages, tables, axis=0)                # (B, mp, KV, ps, hd)
        x = x.astype(jnp.float32)
        if scales is not None:
            x = x * jnp.take(scales, tables, axis=0)[..., None]
        x = jnp.swapaxes(x, 1, 2)                          # (B, KV, mp, ps, hd)
        return x.reshape(b, kv, max_pages * ps, hd)

    k_all = gather(k_pages, k_scale)
    v_all = gather(v_pages, v_scale)
    s = jnp.einsum("bkgh,bkth->bkgt", q.astype(jnp.float32), k_all) * scale
    t = max_pages * ps
    mask = jnp.arange(t)[None, :] < lengths[:, None]       # (B, T)
    s = jnp.where(mask[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bkth->bkgh", p, v_all)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Pallas TPU kernel
# ---------------------------------------------------------------------------
def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, acc_ref, m_ref, l_ref, *, ps: int, g: int,
                  scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lens_ref[b]

    @pl.when(j * ps < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                        # (G, hd)
        # in-register dequant: int8 page × its (token,) per-row scales
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]  # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        col = j * ps + jax.lax.broadcasted_iota(jnp.int32, (g, ps), 1)
        s = jnp.where(col < length, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def _paged_attention_pallas(q, k_pages, v_pages, k_scale, v_scale, tables,
                            lengths, *, sm_scale: Optional[float] = None,
                            interpret: bool = False):
    b, kv, g, hd = q.shape
    ps = k_pages.shape[2]
    max_pages = tables.shape[1]
    scale = sm_scale if sm_scale is not None else hd ** -0.5
    grid = (b, kv, max_pages)

    def page_map(bi, hi, ji, tables_ref, lens_ref):
        return (tables_ref[bi, ji], hi, 0, 0)

    def scale_map(bi, hi, ji, tables_ref, lens_ref):
        return (tables_ref[bi, ji], hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, hi, ji, t, le: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), page_map),
            pl.BlockSpec((1, 1, ps, hd), page_map),
            pl.BlockSpec((1, 1, ps), scale_map),
            pl.BlockSpec((1, 1, ps), scale_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, hi, ji, t, le: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, ps=ps, g=g, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(tables, lengths, q, k_pages, v_pages, k_scale, v_scale)


def paged_attention(q, k_pages, v_pages, k_scale, v_scale, tables, lengths,
                    *, sm_scale: Optional[float] = None, impl: str = "auto",
                    interpret: Optional[bool] = None):
    """Paged decode attention; see :func:`paged_attention_reference` shapes."""
    impl = _resolve(impl)
    if impl == "pallas" and k_scale is not None:
        return _paged_attention_pallas(
            q, k_pages, v_pages, k_scale, v_scale, tables, lengths,
            sm_scale=sm_scale,
            interpret=(not _on_tpu()) if interpret is None else interpret)
    return paged_attention_reference(q, k_pages, v_pages, k_scale, v_scale,
                                     tables, lengths, sm_scale=sm_scale)


def paged_attention_tp(q, k_pages, v_pages, k_scale, v_scale, tables,
                       lengths, *, mesh, axis: str = "model",
                       sm_scale: Optional[float] = None, impl: str = "auto",
                       interpret: Optional[bool] = None):
    """Head-sharded tensor-parallel paged decode attention.

    Same shapes as :func:`paged_attention_reference`; the KV-head dim of
    ``q`` (dim 1) and of the pages/scales must divide ``mesh.shape[axis]``.
    Each device runs the single-device kernel over its local heads of its
    local page shards — block tables and lengths are replicated control
    state, and no KV byte crosses the interconnect.
    """
    kv = q.shape[1]
    if kv % mesh.shape[axis]:
        raise ValueError(
            f"kv heads {kv} not divisible by {axis}={mesh.shape[axis]}")
    head4 = P(None, axis, None, None)
    head3 = P(None, axis, None)
    none_spec = None if k_scale is None else head3

    def body(q_, kp, vp, ks, vs, tb, ln):
        return paged_attention(q_, kp, vp, ks, vs, tb, ln,
                               sm_scale=sm_scale, impl=impl,
                               interpret=interpret)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(head4, head4, head4, none_spec, none_spec,
                  P(None, None), P(None)),
        out_specs=head4, check_rep=False)
    return fn(q, k_pages, v_pages, k_scale, v_scale, tables, lengths)
