"""Chunked paged prefill attention: causal flash over int8 KV pages.

This is the kernel that lets prefill run **directly out of the page pool** —
no dense (B, S, hd) KV staging slab ever exists. A chunk of C new tokens
(positions [q_start, q_start + C)) attends over every page the sequence has
cached so far, including the pages the chunk itself just wrote:

* pages are gathered through the sequence's **block table** with
  scalar-prefetch BlockSpec index maps, ``pages_per_step`` pages per grid
  step — long contexts advance ``pages_per_step × page_size`` tokens per
  step instead of one page per step, amortizing grid-step issue overhead;
* int8 pages are dequantized **in-register** against their per-token scales
  (the quantized cache is never f32 in HBM);
* softmax runs online per q-chunk: running (m, l, acc) scratch in VMEM, one
  output store — the (C, T) score matrix never exists in HBM;
* causality needs masking only against token positions: the chunk sits at
  the *end* of the cached range, so there are no fully-masked kv blocks to
  skip (every page up to ``q_start + C`` is at least partially visible).

Layout: q (KV, C, G, hd) — one sequence, GQA groups folded per kv head.
Pages (P, KV, page_size, hd); scales (P, KV, page_size) — one scale per
(page, head, token) row (write-once pages); table (max_pages,) int32.
Grid (KV, ceil(n_pages / pages_per_step)), kv-steps innermost ('arbitrary').

Besides prefill, this is the **speculative-decoding verify** path: a
γ+1-token panel (last sampled token + γ draft tokens) is exactly a chunk
whose ``q_start`` is wherever decode left off — usually mid-page, which the
per-token scales make safe to resume.

``impl='auto'`` follows the repo convention: Pallas on TPU, the XLA
reference elsewhere. The Pallas path requires int8 pages with scales; float
pages (the bf16 paged pool) route through the reference.

Tensor parallelism: :func:`paged_prefill_attention_tp` shard_maps the kernel
over a mesh's ``model`` axis by kv head (q's leading dim, the pages' head
dim) — each device writes and attends only its head shard of the sequence's
pages; the block table is replicated control state and the KV hot path is
collective-free.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.pltpu_compat import CompilerParams

_NEG = -1e30
_VALID = ("auto", "pallas", "xla")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl not in _VALID:
        raise ValueError(f"impl={impl!r} not in {_VALID}")
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


# ---------------------------------------------------------------------------
# XLA reference (oracle for the kernel; the non-TPU serving path)
# ---------------------------------------------------------------------------
def paged_prefill_reference(q, k_pages, v_pages, k_scale, v_scale, table, *,
                            q_start: int, sm_scale: Optional[float] = None):
    """Gather → dequantize → causally-masked softmax, one jnp expression.

    q: (KV, C, G, hd); pages (P, KV, ps, hd); scales (P, KV, ps) per-token
    or None; table (max_pages,) int32; ``q_start`` static. Returns
    (KV, C, G, hd).
    """
    kv, c, g, hd = q.shape
    ps = k_pages.shape[2]
    kv_len = q_start + c
    n_pages = -(-kv_len // ps)
    slots = table[:n_pages]
    scale = sm_scale if sm_scale is not None else hd ** -0.5

    def gather(pages, scales):
        x = jnp.take(pages, slots, axis=0).astype(jnp.float32)  # (np,KV,ps,hd)
        if scales is not None:
            x = x * jnp.take(scales, slots, axis=0)[..., None]
        return jnp.swapaxes(x, 0, 1).reshape(kv, n_pages * ps, hd)

    k_all = gather(k_pages, k_scale)
    v_all = gather(v_pages, v_scale)
    s = jnp.einsum("kcgh,kth->kcgt", q.astype(jnp.float32), k_all) * scale
    t_pos = jnp.arange(n_pages * ps)
    q_pos = q_start + jnp.arange(c)
    mask = t_pos[None, :] <= q_pos[:, None]                     # (C, T)
    s = jnp.where(mask[None, :, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("kcgt,kth->kcgh", p, v_all)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Pallas TPU kernel
# ---------------------------------------------------------------------------
def _prefill_kernel(table_ref, q_ref, *refs, pp: int, ps: int, g: int,
                    scale: float, q_start: int):
    k_refs = refs[:pp]
    v_refs = refs[pp:2 * pp]
    ks_refs = refs[2 * pp:3 * pp]
    vs_refs = refs[3 * pp:4 * pp]
    o_ref, acc_ref, m_ref, l_ref = refs[4 * pp:]
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                            # (C*G, hd)
    # multi-page kv block: pp pages dequantized in-register (per-token row
    # scales) and stacked
    k = jnp.concatenate(
        [k_refs[i][0, 0].astype(jnp.float32) * ks_refs[i][0, 0][:, None]
         for i in range(pp)], axis=0)                           # (pp*ps, hd)
    v = jnp.concatenate(
        [v_refs[i][0, 0].astype(jnp.float32) * vs_refs[i][0, 0][:, None]
         for i in range(pp)], axis=0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = q.shape[0]
    col = j * pp * ps + jax.lax.broadcasted_iota(jnp.int32, (rows, pp * ps), 1)
    row_pos = q_start + jax.lax.broadcasted_iota(
        jnp.int32, (rows, pp * ps), 0) // g
    s = jnp.where(col <= row_pos, s, _NEG)                      # causal + pad
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_start", "pages_per_step",
                                             "sm_scale", "interpret"))
def _paged_prefill_pallas(q, k_pages, v_pages, k_scale, v_scale, table, *,
                          q_start: int, pages_per_step: int = 1,
                          sm_scale: Optional[float] = None,
                          interpret: bool = False):
    kv, c, g, hd = q.shape
    ps = k_pages.shape[2]
    kv_len = q_start + c
    n_pages = -(-kv_len // ps)
    pp = max(1, min(pages_per_step, n_pages))
    n_steps = -(-n_pages // pp)
    scale = sm_scale if sm_scale is not None else hd ** -0.5
    # pad the table so every (step, page-in-step) lookup is in range; slot 0
    # fetched past kv_len is masked by the position test in-kernel
    tbl = table[:n_pages]
    if n_steps * pp > n_pages:
        tbl = jnp.concatenate(
            [tbl, jnp.zeros((n_steps * pp - n_pages,), jnp.int32)])
    q2 = q.reshape(kv, c * g, hd)

    def page_map(i):
        return lambda hi, ji, t: (t[ji * pp + i], hi, 0, 0)

    def scale_map(i):
        return lambda hi, ji, t: (t[ji * pp + i], hi, 0)

    page_spec = [pl.BlockSpec((1, 1, ps, hd), page_map(i)) for i in range(pp)]
    scale_spec = [pl.BlockSpec((1, 1, ps), scale_map(i)) for i in range(pp)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(kv, n_steps),
        in_specs=[pl.BlockSpec((1, c * g, hd), lambda hi, ji, t: (hi, 0, 0))]
        + page_spec + page_spec + scale_spec + scale_spec,
        out_specs=pl.BlockSpec((1, c * g, hd), lambda hi, ji, t: (hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c * g, hd), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, pp=pp, ps=ps, g=g, scale=scale,
                          q_start=q_start),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kv, c * g, hd), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(tbl, q2, *([k_pages] * pp), *([v_pages] * pp),
      *([k_scale] * pp), *([v_scale] * pp))
    return out.reshape(kv, c, g, hd)


def paged_prefill_attention(q, k_pages, v_pages, k_scale, v_scale, table, *,
                            q_start: int, pages_per_step: int = 1,
                            sm_scale: Optional[float] = None,
                            impl: str = "auto",
                            interpret: Optional[bool] = None):
    """Chunked paged prefill attention; see :func:`paged_prefill_reference`
    for shapes. ``q_start`` / ``pages_per_step`` must be static."""
    impl = _resolve(impl)
    if impl == "pallas" and k_scale is not None:
        return _paged_prefill_pallas(
            q, k_pages, v_pages, k_scale, v_scale, table,
            q_start=q_start, pages_per_step=pages_per_step, sm_scale=sm_scale,
            interpret=(not _on_tpu()) if interpret is None else interpret)
    return paged_prefill_reference(q, k_pages, v_pages, k_scale, v_scale,
                                   table, q_start=q_start, sm_scale=sm_scale)


def paged_prefill_attention_tp(q, k_pages, v_pages, k_scale, v_scale, table,
                               *, mesh, axis: str = "model", q_start: int,
                               pages_per_step: int = 1,
                               sm_scale: Optional[float] = None,
                               impl: str = "auto",
                               interpret: Optional[bool] = None):
    """Head-sharded tensor-parallel chunked paged prefill.

    Same shapes as :func:`paged_prefill_reference`; q's kv dim (dim 0) and
    the pages' head dim must divide ``mesh.shape[axis]``. Each device runs
    the chunk's causal flash attention over its local heads of its local
    page shards; the block table is replicated and no KV byte crosses the
    interconnect.
    """
    kv = q.shape[0]
    if kv % mesh.shape[axis]:
        raise ValueError(
            f"kv heads {kv} not divisible by {axis}={mesh.shape[axis]}")
    qspec = P(axis, None, None, None)
    head4 = P(None, axis, None, None)
    sspec = None if k_scale is None else P(None, axis, None)

    def body(q_, kp, vp, ks, vs, tb):
        return paged_prefill_attention(
            q_, kp, vp, ks, vs, tb, q_start=q_start,
            pages_per_step=pages_per_step, sm_scale=sm_scale, impl=impl,
            interpret=interpret)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(qspec, head4, head4, sspec, sspec, P(None)),
                   out_specs=qspec, check_rep=False)
    return fn(q, k_pages, v_pages, k_scale, v_scale, table)
