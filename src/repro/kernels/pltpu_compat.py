"""Version-compat shims for ``jax.experimental.pallas.tpu``.

The TPU compiler-params dataclass was renamed across JAX releases
(``TPUCompilerParams`` in 0.4.x → ``CompilerParams`` in newer releases).
Every kernel in this package imports the alias from here so the repo runs on
either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
