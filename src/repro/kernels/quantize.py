"""Fused rowwise absmax quantization Pallas kernel.

Dynamic activation quantization is on the critical path of every CAMP GEMM
(the paper's A-panel packing step). Fusing absmax + scale + round + clip into
one VMEM pass avoids materializing the f32 activation twice in HBM.

Each grid step owns a (bm, K) row-block: the absmax reduction needs the whole
row, so K is not blocked (activations rows are ≤ ~32K elements → ≤ 128 KiB
f32 per row, far under VMEM at bm ≤ 256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import INT4_QMAX, INT8_QMAX
from repro.kernels.padding import pad_2d, round_up


def _quantize_kernel(x_ref, q_ref, s_ref, *, qmax):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "interpret"))
def quantize_rowwise_kernel(
    x: jax.Array,            # (M, K) f32/bf16
    *,
    bits: int = 8,
    block_m: int = 256,
    interpret: bool = False,
):
    m, k = x.shape
    bm = min(block_m, m)
    mp = round_up(m, bm)  # padded edge rows quantize to (q=0, scale=1)
    x = pad_2d(x, mp, k)
    qmax = INT8_QMAX if bits == 8 else INT4_QMAX
    q, s = pl.pallas_call(
        functools.partial(_quantize_kernel, qmax=qmax),
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k), jnp.int8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:m], s[:m]
