"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (bit-exact for the
integer paths). They are deliberately written in the most direct way possible —
no blocking, no fusion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import unpack_int4, INT4_QMAX, INT8_QMAX


def dot_i32(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact integer GEMM: int8/int4-valued (M,K)x(K,N) -> int32."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def gemm_i8_ref(a_q, b_q, a_scale, b_scale, out_dtype=jnp.float32):
    """CAMP int8 GEMM oracle: exact int32 accumulate + Cartesian scale epilogue.

    a_q: (M, K) int8, b_q: (K, N) int8,
    a_scale: (M, 1) f32, b_scale: (1, N) f32.
    """
    acc = dot_i32(a_q, b_q)
    return (acc.astype(jnp.float32) * (a_scale * b_scale)).astype(out_dtype)


def gemm_w4_ref(a_q, b_packed, a_scale, b_scale, out_dtype=jnp.float32):
    """CAMP a8w4 GEMM oracle: unpack int4 weights then exact int32 GEMM."""
    k = a_q.shape[-1]
    b_q = unpack_int4(b_packed, k)
    return gemm_i8_ref(a_q, b_q, a_scale, b_scale, out_dtype)


def gemm_a4w4_ref(a_packed, b_packed, k, a_scale, b_scale, out_dtype=jnp.float32):
    """CAMP int4×int4 GEMM oracle: both operands packed along K."""
    a_q = unpack_int4(a_packed.T, k).T  # a packed along last (K) axis
    b_q = unpack_int4(b_packed, k)
    return gemm_i8_ref(a_q, b_q, a_scale, b_scale, out_dtype)


def quantize_rowwise_ref(x, bits=8):
    """Oracle for the fused rowwise-quantize kernel."""
    qmax = INT8_QMAX if bits == 8 else INT4_QMAX
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale


def attention_ref(q, k, v, *, causal=True, scale=None):
    """Oracle for the flash-attention kernel. q,k,v: (S, H) per head-batch slice
    or (B, H, S, D); this oracle handles (B, H, S, D)."""
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
