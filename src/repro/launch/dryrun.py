import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count at first init.
# 512 placeholder host devices exist ONLY inside this dry-run process.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the real step function (train_step / prefill_step / decode_step)
     with ShapeDtypeStruct inputs (no allocation),
  3. ``jit(...).lower(...).compile()`` — sharding mismatches, OOM-at-compile
     or unsupported collectives fail HERE, which is the point,
  4. records ``memory_analysis()`` (bytes/device — proves fit),
     ``cost_analysis()`` (per-partition FLOPs/bytes) and the collective
     schedule parsed from the optimized HLO,
  5. derives the three roofline terms (v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
     ~50 GB/s/link ICI) and writes one JSON artifact per cell.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from collections import defaultdict
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, get_config
from repro.configs.shapes import SHAPES, runnable
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig
from repro.models.transformer import init_params, quantize_params
from repro.optim import adamw
from repro.parallel.sharding import make_rules, mesh_context, params_pspecs, spec_for
from repro.serving.engine import build_decode_step, build_prefill_step
from repro.train import build_train_step

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16 (int8 ≈ 394e12)
HBM_BW = 819e9             # B/s
LINK_BW = 50e9             # B/s per ICI link
HBM_BYTES = 16 * 2**30

BIG_PARAM_THRESHOLD = 20e9   # int8 optimizer moments above this


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, batch: int, seq: int, rules, mesh):
    if cfg.embedding_inputs:
        inp = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)
        inp_spec = spec_for(inp.shape, ("batch", "seq_act", None), rules, mesh)
    else:
        inp = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        inp_spec = spec_for(inp.shape, ("batch", "seq_act"), rules, mesh)
    lab = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    lab_spec = spec_for(lab.shape, ("batch", "seq_act"), rules, mesh)
    return ({"inputs": inp, "labels": lab},
            {"inputs": NamedSharding(mesh, inp_spec),
             "labels": NamedSharding(mesh, lab_spec)})


_CACHE_AXES = {
    "k": ("batch", "kv_heads", "seq_kv", None),
    "v": ("batch", "kv_heads", "seq_kv", None),
    "kv_scale": ("batch", "kv_heads", None),
    "h": ("batch", "ssm_inner", None),
    "conv": ("batch", None, "ssm_inner"),
    "s": ("batch", "heads", None, None),
    "x_prev": ("batch", None),
}


def cache_pspecs(tree, rules, mesh):
    from repro.serving.kv_cache import DenseKVCache

    def walk(node, name=None):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, DenseKVCache):
            opt = lambda x: None if x is None else walk(x, "kv_scale")  # noqa: E731
            return DenseKVCache(k=walk(node.k, "k"), v=walk(node.v, "v"),
                                k_scale=opt(node.k_scale),
                                v_scale=opt(node.v_scale),
                                page_size=node.page_size)
        names = _CACHE_AXES.get(name, (None,) * len(node.shape))
        return NamedSharding(mesh, spec_for(node.shape, names, rules, mesh))
    return walk(tree)


def opt_pspecs(params_specs, quantized: bool):
    """Moment pspecs mirror the parameter pspecs (int8 moments keep the param
    shape; their (…,1) scales drop the last axis binding)."""
    def one(spec):
        if not quantized:
            return spec
        scale_spec = P(*(tuple(spec) [:-1] + (None,))) if len(spec) else P()
        return {"q": spec, "scale": scale_spec}
    moments = jax.tree_util.tree_map(one, params_specs,
                                     is_leaf=lambda x: isinstance(x, P))
    return {"m": moments, "v": moments, "count": P()}


def serve_cfg(cfg: ModelConfig, kind: str) -> ModelConfig:
    """Per-kind config tweaks (q-chunked exact attention for long prefill)."""
    if kind == "prefill":
        # heads that don't shard over model=16 leave attention replicated —
        # shrink the q chunk so per-chunk score buffers stay a few GiB.
        chunk = 4096 if (cfg.n_heads == 0 or cfg.n_heads % 16 == 0) else 512
        return dataclasses.replace(cfg, attn_q_chunk=chunk, remat=True)
    if kind == "train":
        # q-chunked causal attention bounds the remat-recompute score buffer
        return dataclasses.replace(cfg, attn_q_chunk=1024)
    return cfg


# ---------------------------------------------------------------------------
# Cell builders: return (step_fn, args, in_shardings, donate)
# ---------------------------------------------------------------------------
def build_train_cell(cfg: ModelConfig, shape, mesh, rules):
    cfg = serve_cfg(cfg, "train")
    quant_moments = cfg.param_count() > BIG_PARAM_THRESHOLD
    opt = adamw(lr=1e-4, quantize_moments=quant_moments)
    step_fn = build_train_step(cfg, opt)

    params_shape = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    opt_shape = jax.eval_shape(lambda: opt.init(params_shape))
    p_specs = params_pspecs(params_shape, rules, mesh)
    state_shape = {"params": params_shape, "opt": opt_shape,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
    state_specs = {"params": p_specs,
                   "opt": opt_pspecs(p_specs, quant_moments),
                   "step": P()}
    state_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))
    batch, b_shardings = batch_specs(cfg, shape.global_batch, shape.seq_len,
                                     rules, mesh)
    return (step_fn, (state_shape, batch), (state_shardings, b_shardings), (0,))


def _serve_params(cfg: ModelConfig, qmode: str, mesh, rules):
    def make():
        p = init_params(jax.random.PRNGKey(0), cfg)
        return quantize_params(p, cfg, qmode)
    params_shape = jax.eval_shape(make)
    p_specs = params_pspecs(params_shape, rules, mesh)
    from repro.core.quant import QuantizedTensor

    def conv(node):
        if isinstance(node, QuantizedTensor):
            return QuantizedTensor(q=NamedSharding(mesh, node.q),
                                   scale=NamedSharding(mesh, node.scale),
                                   bits=node.bits, shape=node.shape)
        if isinstance(node, P):
            return NamedSharding(mesh, node)
        return node
    p_shardings = jax.tree_util.tree_map(
        conv, p_specs, is_leaf=lambda x: isinstance(x, (P, QuantizedTensor)))
    return params_shape, p_shardings


def build_prefill_cell(cfg: ModelConfig, shape, mesh, rules, qmode: str):
    cfg = serve_cfg(cfg, "prefill")
    step = build_prefill_step(cfg)
    params_shape, p_shard = _serve_params(cfg, qmode, mesh, rules)
    from repro.serving.engine import init_serve_caches
    caches_shape = jax.eval_shape(
        lambda: init_serve_caches(cfg, shape.global_batch, shape.seq_len))
    c_shard = cache_pspecs(caches_shape, rules, mesh)
    if cfg.embedding_inputs:
        inp = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len,
                                    cfg.d_model), jnp.bfloat16)
        i_spec = spec_for(inp.shape, ("batch", "seq_act", None), rules, mesh)
    else:
        inp = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
        i_spec = spec_for(inp.shape, ("batch", "seq_act"), rules, mesh)
    return (step, (params_shape, inp, caches_shape),
            (p_shard, NamedSharding(mesh, i_spec), c_shard), (2,))


def build_decode_cell(cfg: ModelConfig, shape, mesh, rules, qmode: str,
                      kv_dtype=None):
    cfg = serve_cfg(cfg, "decode")
    step = build_decode_step(cfg)
    params_shape, p_shard = _serve_params(cfg, qmode, mesh, rules)
    from repro.serving.engine import init_serve_caches
    caches_shape = jax.eval_shape(
        lambda: init_serve_caches(cfg, shape.global_batch, shape.seq_len,
                                  kv_dtype=kv_dtype))
    c_shard = cache_pspecs(caches_shape, rules, mesh)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_spec = spec_for(tok.shape, ("batch", None), rules, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return (step, (params_shape, caches_shape, tok, pos),
            (p_shard, c_shard, NamedSharding(mesh, t_spec), None), (1,))


# ---------------------------------------------------------------------------
# HLO collective parsing → wire bytes per device
# ---------------------------------------------------------------------------
_DT_BYTES = {"f64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
             "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
             "u64": 8, "c64": 8}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\[[\d,]+\]<=\[[\d,x]+\])")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DT_BYTES.get(dt, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return total_devices
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(1, len(first.split(",")))
    dims = [int(x) for x in g[1:g.index("]")].split(",")]
    return dims[-1] if len(dims) >= 2 else dims[0]


def parse_collectives(hlo_text: str, total_devices: int):
    """Per-device wire-byte estimate per collective kind (ring algorithms).

    HLO here is post-SPMD-partitioning: result shapes are per-device. With
    result bytes R on a ring of n participants:
      all-gather      R(n-1)/n    (R is the gathered full block)
      all-reduce      2R(n-1)/n
      reduce-scatter  R(n-1)      (R is the scattered shard)
      all-to-all      R(n-1)/n
      collective-permute  R
    ``-done`` halves of async pairs are skipped (counted at ``-start``).
    """
    stats = defaultdict(lambda: {"count": 0, "result_bytes": 0, "wire_bytes": 0})
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        mm = re.search(r"\b(" + "|".join(_COLL_KINDS) + r")(-start)?\(", line)
        if not mm or f"{mm.group(1)}-done" in line:
            continue
        kind = mm.group(1)
        lhs = line.partition("=")[0] + line.partition("=")[2].split(kind)[0]
        shapes = _SHAPE_RE.findall(lhs)
        if not shapes:
            continue
        r_bytes = max(_shape_bytes(dt, dims) for dt, dims in shapes)
        n = _group_size(line, total_devices)
        if n <= 1:
            continue
        if kind == "all-gather":
            wire = r_bytes * (n - 1) / n
        elif kind == "all-reduce":
            wire = 2 * r_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = r_bytes * (n - 1)
        elif kind == "all-to-all":
            wire = r_bytes * (n - 1) / n
        else:  # collective-permute
            wire = r_bytes
        s = stats[kind]
        s["count"] += 1
        s["result_bytes"] += r_bytes
        s["wire_bytes"] += int(wire)
    return dict(stats)


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------
def model_flops(cfg: ModelConfig, shape) -> float:
    """Global MODEL_FLOPS per step: 6·N_active·tokens (train) /
    2·N_active·tokens (serve)."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch          # decode: 1 token/seq


def roofline(record: dict, n_devices: int, cfg: ModelConfig, shape) -> dict:
    flops = record["cost"].get("flops", 0.0)
    bytes_acc = record["cost"].get("bytes accessed", 0.0)
    wire = sum(s["wire_bytes"] for s in record["collectives"].values())
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = wire / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape) / n_devices
    return {
        **terms,
        "bottleneck": bottleneck,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": (mf / flops) if flops else 0.0,
        "roofline_frac": (mf / PEAK_FLOPS) / max(compute_s, memory_s,
                                                 collective_s, 1e-30),
        "wire_bytes": wire,
    }


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, *, multi_pod: bool, qmode: str = "none",
             kv_dtype=None, rules_override=None, cfg_override=None,
             verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch, qmode=qmode)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rules = make_rules(mode=shape.kind, multi_pod=multi_pod, family=cfg.family)
    if rules_override:
        rules.update(rules_override)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "qmode": qmode, "kv_dtype": kv_dtype,
        "n_devices": n_dev,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    if not runnable(cfg.family, shape):
        rec["status"] = "SKIP(sub-quadratic-only)"
        return rec

    t0 = time.time()
    try:
        with mesh_context(mesh, rules):
            if shape.kind == "train":
                step, args, shardings, donate = build_train_cell(cfg, shape, mesh, rules)
            elif shape.kind == "prefill":
                step, args, shardings, donate = build_prefill_cell(cfg, shape, mesh, rules, qmode)
            else:
                step, args, shardings, donate = build_decode_cell(cfg, shape, mesh, rules,
                                                                  qmode, kv_dtype)
            lowered = jax.jit(step, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
    except Exception as exc:  # noqa: BLE001 — a failed cell is a recorded bug
        rec["status"] = "FAIL"
        rec["error"] = f"{type(exc).__name__}: {exc}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        return rec

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    text = compiled.as_text()
    colls = parse_collectives(text, n_dev)
    rec.update({
        "status": "OK",
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            "fits_16g": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                         + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
                        < HBM_BYTES,
        },
        "cost": {k: ca[k] for k in ("flops", "bytes accessed") if k in ca},
        "collectives": colls,
        "hlo_ops": len(text.splitlines()),
    })
    rec["roofline"] = roofline(rec, n_dev, cfg, shape)
    if verbose:
        m = rec["memory"]
        r = rec["roofline"]
        print(f"  mem/device: args={m['argument_bytes']/2**30:.2f}GiB "
              f"temp={m['temp_bytes']/2**30:.2f}GiB peak={m['peak_bytes']/2**30:.2f}GiB "
              f"fits16G={m['fits_16g']}")
        print(f"  roofline: compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms collective={r['collective_s']*1e3:.2f}ms "
              f"→ {r['bottleneck']} | useful={r['useful_flops_ratio']:.2f} "
              f"frac={r['roofline_frac']:.3f}")
    return rec


def cell_id(arch, shape, multi_pod, qmode, kv_dtype=None, tag=""):
    mesh = "multi" if multi_pod else "single"
    kv = f"__kv{kv_dtype}" if kv_dtype else ""
    t = f"__{tag}" if tag else ""
    return f"{arch}__{shape}__{mesh}__{qmode}{kv}{t}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--qmode", default=None,
                    help="override serve qmode (default: none for train, "
                         "none+w8a8 sweep for serve)")
    ap.add_argument("--kv-dtype", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    archs = list(REGISTRY) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape_name in shapes:
            kind = SHAPES[shape_name].kind
            if args.qmode is not None:
                qmodes = [args.qmode]
            else:
                # baseline = paper-faithful: bf16 training, CAMP w8a8 serving
                qmodes = ["none"] if kind == "train" else ["w8a8"]
            for multi_pod in meshes:
                for qmode in qmodes:
                    cid = cell_id(arch, shape_name, multi_pod, qmode, args.kv_dtype)
                    path = out / f"{cid}.json"
                    if path.exists() and not args.force:
                        print(f"[cached] {cid}")
                        results.append(json.loads(path.read_text()))
                        continue
                    print(f"[run] {cid}", flush=True)
                    rec = run_cell(arch, shape_name, multi_pod=multi_pod,
                                   qmode=qmode, kv_dtype=args.kv_dtype)
                    path.write_text(json.dumps(rec, indent=1, default=float))
                    print(f"  -> {rec['status']}"
                          + (f" ({rec.get('error','')})" if rec["status"] == "FAIL" else ""),
                          flush=True)
                    results.append(rec)

    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"].startswith("SKIP") for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n=== dry-run summary: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL "
          f"of {len(results)} cells ===")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
