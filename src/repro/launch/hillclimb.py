import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: the three selected (arch × shape) cells, each with
an explicit hypothesis → change ladder. Every variant is a real dry-run
compile (tagged artifact JSON); the EXPERIMENTS.md §Perf table is generated
from these.

  A. qwen2-72b × decode_32k  — most representative of the paper's technique:
     decode is weight-bandwidth-bound; each quantization rung should cut the
     memory term by the storage ratio.
       A1 bf16 (reference)  → A0 w8a8 (paper-faithful baseline)
       → A2 w4a8 (packed int4 weights) → A3 w4a8 + int8 KV cache
  B. (most collective-bound train cell — selected from the baseline table)
       B0 baseline → B1 bigger MoE routing groups (fewer, larger a2a)
       → B2 no-remat (trade memory for recompute-collectives)
  C. pixtral-12b × prefill_32k — worst roofline fraction:
       C0 w8a8 chunked-attention baseline → C1 w4a8 weights
       → C2 q-chunk 8192 (halve score-buffer writebacks)
       → C3 flash-attention kernel (analytic memory-term entry: kernel
         validated in interpret mode; Mosaic can't lower on the CPU backend,
         so its roofline row is computed from first principles and marked
         `modeled`).

Usage:  PYTHONPATH=src python -m repro.launch.hillclimb --cell A
"""
import argparse
import json
from pathlib import Path

from repro.launch import dryrun as dr

OUT = Path("artifacts/dryrun")


def _run(tag: str, **kw):
    cid = dr.cell_id(kw["arch"], kw["shape_name"], kw.get("multi_pod", False),
                     kw.get("qmode", "none"), kw.get("kv_dtype"), tag)
    path = OUT / f"{cid}.json"
    if path.exists() and not kw.pop("force", False):
        print(f"[cached] {cid}")
        return json.loads(path.read_text())
    print(f"[hillclimb] {cid}", flush=True)
    kw.pop("force", None)
    rec = dr.run_cell(**kw)
    rec["tag"] = tag
    path.write_text(json.dumps(rec, indent=1, default=float))
    print(f"  -> {rec['status']}")
    return rec


def cell_a(force=False):
    base = dict(arch="qwen2-72b", shape_name="decode_32k", multi_pod=False,
                force=force)
    _run("A1_bf16", qmode="none", **base)
    _run("A0_w8a8", qmode="w8a8", **base)          # == sweep baseline
    _run("A2_w4a8", qmode="w4a8", **base)
    _run("A3_w4a8_kv8", qmode="w4a8", kv_dtype="int8", **base)


def cell_b(arch="llama4-maverick-400b-a17b", force=False):
    """Most collective-bound cell: MoE expert-parallel decode (token a2a +
    expert-output combine-gather over the data axis)."""
    base = dict(arch=arch, shape_name="decode_32k", multi_pod=False,
                force=force)
    _run("B0_w8a8", qmode="w8a8", **base)          # == sweep baseline
    # B1: int4 experts — halves the resident expert bytes AND the dequant
    # side of every gather the combine path makes.
    _run("B1_w4a8", qmode="w4a8", **base)
    # B2: experts sharded over model instead of data (TP-experts): combine
    # gathers move to the model axis; token a2a disappears, weight residency
    # per device grows 16×/|data| — hypothesis: worse memory, less wire.
    _run("B2_experts_model", qmode="w8a8",
         rules_override={"expert": ("model",), "expert_ff": ("data",)}, **base)
    # B3: int8 KV on top of the winner
    _run("B3_w4a8_kv8", qmode="w4a8", kv_dtype="int8", **base)


def cell_c(force=False):
    base = dict(arch="pixtral-12b", shape_name="prefill_32k", multi_pod=False,
                force=force)
    _run("C0_w8a8", qmode="w8a8", **base)          # == sweep baseline
    _run("C1_w4a8", qmode="w4a8", **base)
    _run("C2_qchunk8k", qmode="w8a8",
         cfg_override={"attn_q_chunk": 8192}, **base)
    # C3: flash-attention — analytic roofline entry (kernel interpret-tested)
    rec = _flash_modeled_entry()
    (OUT / "pixtral-12b__prefill_32k__single__w8a8__C3_flash.json").write_text(
        json.dumps(rec, indent=1, default=float))
    print("[hillclimb] C3_flash (modeled) written")


def _flash_modeled_entry():
    """First-principles memory-term for flash-attention prefill (pixtral).

    Chunked-attention baseline writes+reads per layer per device:
      scores f32 (B_loc, H_loc, S, S) once written + read  (the term the
      kernel removes), plus Q/K/V/O traffic.
    Flash kernel traffic: Q+K+V+O exactly once (scores live in VMEM).
    """
    from repro.configs import get_config
    cfg = get_config("pixtral-12b")
    B_loc, S, H_loc, Dh = 2, 32768, cfg.n_heads // 16, cfg.hd
    L = cfg.n_layers
    qkvo = 4 * B_loc * S * H_loc * Dh * 2                      # bf16
    scores_rw = 2 * B_loc * H_loc * S * S * 4                  # f32 w+r
    base_attn_bytes = L * (qkvo + scores_rw)
    flash_attn_bytes = L * qkvo
    # non-attention bytes: take the compiled C0 record and subtract the
    # score traffic analytically.
    c0 = json.loads((OUT / "pixtral-12b__prefill_32k__single__w8a8__C0_w8a8.json")
                    .read_text())
    total_bytes = c0["cost"]["bytes accessed"]
    new_bytes = max(total_bytes - (base_attn_bytes - flash_attn_bytes), 0.0)
    rec = dict(c0)
    rec["tag"] = "C3_flash_modeled"
    rec["provenance"] = ("memory term recomputed analytically: chunked-score "
                         "HBM traffic removed (flash kernel keeps scores in "
                         "VMEM); kernel itself validated vs oracle in "
                         "interpret mode (tests/test_kernels.py)")
    rec["cost"] = dict(c0["cost"], **{"bytes accessed": new_bytes})
    from repro.configs.shapes import SHAPES
    rec["collectives"] = c0["collectives"]
    rec["roofline"] = dr.roofline(rec, 256, cfg, SHAPES["prefill_32k"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=["A", "B", "C", "all"], default="all")
    ap.add_argument("--b-arch", default="jamba-v0.1-52b")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    if args.cell in ("A", "all"):
        cell_a(args.force)
    if args.cell in ("C", "all"):
        cell_c(args.force)
    if args.cell in ("B", "all"):
        cell_b(args.b_arch, args.force)


if __name__ == "__main__":
    main()
