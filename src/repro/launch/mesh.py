"""Production mesh definition.

Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — 'pod' is a pure
data-parallel axis across the DCN/ICI-superpod boundary.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale distribution tests (8 virtual devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
