"""Production mesh definition.

Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — 'pod' is a pure
data-parallel axis across the DCN/ICI-superpod boundary.

Serving:    (data, model) with the model axis carrying tensor parallelism —
:func:`make_serving_mesh` sizes it from the requested TP degree.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. ``jax.make_mesh`` grew its ``axis_types``
kwarg after 0.4.37; :func:`_make_mesh` feature-detects it so every mesh in
the repo (including the 8-virtual-device CPU CI meshes) builds on either
API generation.
"""
from __future__ import annotations

import inspect

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh with Auto axis_types where the installed jax has them."""
    kwargs = {}
    if ("axis_types" in inspect.signature(jax.make_mesh).parameters
            and hasattr(jax.sharding, "AxisType")):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale distribution tests (8 virtual devices)."""
    return _make_mesh(shape, axes)


def make_serving_mesh(tp: int = 1, *, data: int = 0):
    """(data, model) mesh for tensor-parallel serving.

    ``tp`` is the model-axis (tensor-parallel) degree; ``data=0`` spreads the
    remaining local devices over the data axis. The paged serving engine
    shards KV page storage and the projection weights over ``model`` and
    keeps scheduler state replicated (see :mod:`repro.serving`).
    """
    n = len(jax.devices())
    if n % tp:
        raise ValueError(f"tp={tp} does not divide device count {n}")
    data = data or n // tp
    return _make_mesh((data, tp), ("data", "model"))
