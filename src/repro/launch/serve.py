"""Serving entrypoint: CAMP-quantized batched generation.

CPU-scale e2e (runs in this container):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --qmode w8a8 --batch 4 --prompt-len 32 --steps 16

Tensor-parallel serving (8 virtual devices, model axis 4):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --qmode w8a8 --tp 4 --batch 4 --prompt-len 32 --steps 16

With ``--tp``, the weights are resident model-sharded (column-parallel
q/kv/up/gate, row-parallel wo/down via the serve rule table), the paged KV
pool is head-sharded, and the engine runs every step under the serve-mode
mesh context. ``--tp-int8-reduce`` compresses the row-parallel all-reduces
to int8 on the wire.

Speculative decoding (draft–verify over the paged int8 cache):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --qmode w8a8 --batch 1 --steps 32 --spec-method ngram --spec-gamma 4

``--spec-method draft`` drives a small draft LM (``--spec-draft-config``,
e.g. ``qwen2-0.5b`` drafting for ``qwen2-72b``) over its own paged pool;
``--spec-gamma auto`` picks the window from the measured acceptance rate
through the autotune cache's ``spec|`` keys. The γ+1-row verify GEMM
shapes are pre-tuned alongside the decode/prefill shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import autotune
from repro.launch.mesh import make_serving_mesh
from repro.models import init_params, quantize_params
from repro.parallel.sharding import (effective_model_shards, make_rules,
                                     params_pspecs)
from repro.serving.engine import generate, warm_gemm_autotune
from repro.serving.spec_decode import SpecConfig


def shard_params(params, mesh):
    """device_put the params tree to its serve-rule shardings.

    QuantizedTensor leaves place their int payload and (1, N) scale
    separately (column-consistent specs from ``params_pspecs``).
    """
    from jax.sharding import NamedSharding

    from repro.core.quant import QuantizedTensor

    rules = make_rules("serve")
    specs = params_pspecs(params, rules, mesh)

    def put(x, s):
        if isinstance(x, QuantizedTensor):
            return QuantizedTensor(
                q=jax.device_put(x.q, NamedSharding(mesh, s.q)),
                scale=jax.device_put(x.scale, NamedSharding(mesh, s.scale)),
                bits=x.bits, shape=x.shape)
        return jax.device_put(x, NamedSharding(mesh, s))

    return jax.tree_util.tree_map(
        put, params, specs,
        is_leaf=lambda x: isinstance(x, QuantizedTensor)
        or (hasattr(x, "shape") and not isinstance(x, dict)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--qmode", default="w8a8",
                    choices=["none", "w8a8", "w4a8", "w4a4", "w8a16", "w4a16"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--sample", default="greedy", choices=["greedy", "temperature"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis (tensor-parallel) degree; 1 = off")
    ap.add_argument("--tp-int8-reduce", action="store_true",
                    help="int8-compress the row-parallel all-reduces")
    ap.add_argument("--spec-method", default="off",
                    choices=["off", "ngram", "draft"],
                    help="speculative decoding: model-free n-gram lookup "
                         "or a small draft model")
    ap.add_argument("--spec-gamma", default="4",
                    help="speculation window (draft tokens/step), or 'auto' "
                         "to pick from the measured acceptance rate")
    ap.add_argument("--spec-draft-config", default="qwen2-0.5b",
                    help="draft model arch for --spec-method draft "
                         "(always built with --reduced shapes)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced, qmode=args.qmode)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    if args.qmode != "none":
        t0 = time.time()
        params = quantize_params(params, cfg, args.qmode)
        print(f"[serve] PTQ to {args.qmode} in {time.time()-t0:.2f}s")

    spec = None
    if args.spec_method != "off":
        gamma = args.spec_gamma if args.spec_gamma == "auto" \
            else int(args.spec_gamma)
        draft_cfg = draft_params = None
        if args.spec_method == "draft":
            draft_cfg = get_config(args.spec_draft_config, reduced=True,
                                   qmode=args.qmode)
            draft_params = init_params(jax.random.fold_in(key, 1), draft_cfg)
            if args.qmode != "none":
                draft_params = quantize_params(draft_params, draft_cfg,
                                               args.qmode)
        spec = SpecConfig(method=args.spec_method, gamma=gamma,
                          draft_cfg=draft_cfg, draft_params=draft_params)
        # pre-tune the γ+1-row verify panels next to the decode shapes
        gammas = autotune.SPEC_GAMMAS if gamma == "auto" else (gamma,)
        warm_gemm_autotune(cfg, batch_sizes=(1, args.batch),
                           tp=args.tp, spec_gammas=gammas)
        print(f"[serve] speculative decoding: {args.spec_method}, "
              f"gamma={gamma}")

    mesh = None
    if args.tp > 1:
        mesh = make_serving_mesh(args.tp)
        params = shard_params(params, mesh)
        tp_eff = effective_model_shards(mesh, cfg.n_kv_heads)
        sharded = tp_eff if tp_eff > 1 else "replicated"
        print(f"[serve] mesh {dict(mesh.shape)}; kv-head sharding: {sharded}")

    if cfg.embedding_inputs:
        prompt = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)
    else:
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    if spec is None:
        toks = generate(params, cfg, prompt, steps=args.steps, key=key,
                        sample=args.sample, mesh=mesh,
                        tp_int8_reduce=args.tp_int8_reduce)
    else:
        # drive the engine directly so the acceptance stats are reportable
        from repro.serving.engine import ContinuousBatchingEngine
        from repro.serving.kv_cache import round_up
        eng = ContinuousBatchingEngine(
            params, cfg, kv_dtype="int8",
            capacity_tokens=args.batch * round_up(
                args.prompt_len + args.steps, 128),
            sample=args.sample, key=key, mesh=mesh,
            tp_int8_reduce=args.tp_int8_reduce, spec=spec)
        sids = [eng.submit(prompt[i], args.steps)
                for i in range(args.batch)]
        outs = eng.run()
        toks = jnp.asarray([outs[s] for s in sids], jnp.int32)
        s = eng.spec_summary()
        print(f"[serve] spec: {s['spec_steps']} verify steps, acceptance "
              f"{s['acceptance_rate']:.2f}, "
              f"{s['mean_tokens_per_step']:.2f} tokens/step "
              f"(gamma={s['gamma']})")
    dt = time.time() - t0
    n_new = toks.shape[0] * toks.shape[1]
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({n_new/dt:.1f} tok/s incl. compile)")
    print(f"[serve] sample row: {toks[0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
