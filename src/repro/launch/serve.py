"""Serving entrypoint: CAMP-quantized batched generation.

CPU-scale e2e (runs in this container):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --qmode w8a8 --batch 4 --prompt-len 32 --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params, quantize_params
from repro.serving.engine import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--qmode", default="w8a8",
                    choices=["none", "w8a8", "w4a8", "w4a4", "w8a16", "w4a16"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--sample", default="greedy", choices=["greedy", "temperature"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced, qmode=args.qmode)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    if args.qmode != "none":
        t0 = time.time()
        params = quantize_params(params, cfg, args.qmode)
        print(f"[serve] PTQ to {args.qmode} in {time.time()-t0:.2f}s")

    if cfg.embedding_inputs:
        prompt = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)
    else:
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    toks = generate(params, cfg, prompt, steps=args.steps, key=key,
                    sample=args.sample)
    dt = time.time() - t0
    n_new = toks.shape[0] * toks.shape[1]
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({n_new/dt:.1f} tok/s incl. compile)")
    print(f"[serve] sample row: {toks[0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
