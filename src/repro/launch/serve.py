"""Serving entrypoint: CAMP-quantized batched generation.

CPU-scale e2e (runs in this container):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --qmode w8a8 --batch 4 --prompt-len 32 --steps 16

Tensor-parallel serving (8 virtual devices, model axis 4):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --qmode w8a8 --tp 4 --batch 4 --prompt-len 32 --steps 16

With ``--tp``, the weights are resident model-sharded (column-parallel
q/kv/up/gate, row-parallel wo/down via the serve rule table), the paged KV
pool is head-sharded, and the engine runs every step under the serve-mode
mesh context. ``--tp-int8-reduce`` compresses the row-parallel all-reduces
to int8 on the wire.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_serving_mesh
from repro.models import init_params, quantize_params
from repro.parallel.sharding import (effective_model_shards, make_rules,
                                     params_pspecs)
from repro.serving.engine import generate


def shard_params(params, mesh):
    """device_put the params tree to its serve-rule shardings.

    QuantizedTensor leaves place their int payload and (1, N) scale
    separately (column-consistent specs from ``params_pspecs``).
    """
    from jax.sharding import NamedSharding

    from repro.core.quant import QuantizedTensor

    rules = make_rules("serve")
    specs = params_pspecs(params, rules, mesh)

    def put(x, s):
        if isinstance(x, QuantizedTensor):
            return QuantizedTensor(
                q=jax.device_put(x.q, NamedSharding(mesh, s.q)),
                scale=jax.device_put(x.scale, NamedSharding(mesh, s.scale)),
                bits=x.bits, shape=x.shape)
        return jax.device_put(x, NamedSharding(mesh, s))

    return jax.tree_util.tree_map(
        put, params, specs,
        is_leaf=lambda x: isinstance(x, QuantizedTensor)
        or (hasattr(x, "shape") and not isinstance(x, dict)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--qmode", default="w8a8",
                    choices=["none", "w8a8", "w4a8", "w4a4", "w8a16", "w4a16"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--sample", default="greedy", choices=["greedy", "temperature"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis (tensor-parallel) degree; 1 = off")
    ap.add_argument("--tp-int8-reduce", action="store_true",
                    help="int8-compress the row-parallel all-reduces")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced, qmode=args.qmode)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    if args.qmode != "none":
        t0 = time.time()
        params = quantize_params(params, cfg, args.qmode)
        print(f"[serve] PTQ to {args.qmode} in {time.time()-t0:.2f}s")

    mesh = None
    if args.tp > 1:
        mesh = make_serving_mesh(args.tp)
        params = shard_params(params, mesh)
        tp_eff = effective_model_shards(mesh, cfg.n_kv_heads)
        sharded = tp_eff if tp_eff > 1 else "replicated"
        print(f"[serve] mesh {dict(mesh.shape)}; kv-head sharding: {sharded}")

    if cfg.embedding_inputs:
        prompt = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)
    else:
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    toks = generate(params, cfg, prompt, steps=args.steps, key=key,
                    sample=args.sample, mesh=mesh,
                    tp_int8_reduce=args.tp_int8_reduce)
    dt = time.time() - t0
    n_new = toks.shape[0] * toks.shape[1]
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({n_new/dt:.1f} tok/s incl. compile)")
    print(f"[serve] sample row: {toks[0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
