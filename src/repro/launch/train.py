"""Training entrypoint.

CPU-scale e2e (runs in this container):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

On a real cluster the same entrypoint takes --mesh single|multi and shards
state/batches with the production rules (the multi-pod dry-run proves those
configs compile; this process would be one host of the jax.distributed job).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.optim import adamw, cosine_schedule
from repro.train import build_train_step, init_train_state
from repro.train import loop as loop_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", default=None, choices=[None, "int8"])
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    opt = adamw(lr=cosine_schedule(args.lr, args.steps // 10, args.steps),
                weight_decay=0.01, quantize_moments=args.int8_moments)
    step_fn = build_train_step(cfg, opt, grad_accum=args.grad_accum,
                               compress_grads=args.compress_grads)
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    data = SyntheticLMData(
        cfg.vocab_size, args.batch, args.seq, seed=args.seed,
        embedding_dim=cfg.d_model if cfg.embedding_inputs else None)
    state, hist = loop_lib.run(step_fn, state, data, steps=args.steps,
                               ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every)
    first = np.mean(hist["loss"][:5]) if hist["loss"] else float("nan")
    last = np.mean(hist["loss"][-5:]) if hist["loss"] else float("nan")
    print(f"[train] loss {first:.3f} → {last:.3f} over {len(hist['loss'])} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
