"""Model substrate: config-driven decoder LMs (attn / mamba / rwkv mixers)."""
from repro.models.config import ModelConfig
from repro.models.transformer import (
    forward,
    init_caches,
    init_params,
    loss_fn,
    quantize_params,
)
