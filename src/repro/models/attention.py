"""GQA attention with rope / qk-norm / qkv-bias, KV cache, q-chunked prefill.

Grouped computation never materializes repeated KV heads: q is viewed as
(B, S, KV, H/KV, hd) and contracted against (B, T, KV, hd) directly.

Causal prefill at 32k uses **q-chunking** (python-unrolled, so the multi-pod
dry-run's cost analysis sees every FLOP): each (B, chunk, ...) q-slice attends
to the full KV — exact, no online-softmax state, peak memory ∝ chunk × T
instead of T × T.

KV caching goes through :mod:`repro.serving.kv_cache`:

* :class:`~repro.serving.kv_cache.DenseKVCache` — the (B, max_len) slab
  (training/prefill and the legacy batched decode path). int8 slabs carry
  per-page dynamic scales; all conversion lives in the cache module.
* :class:`~repro.serving.kv_cache.PagedDecodeCache` — a page-pool view used
  by the continuous-batching engine: append goes to block-table pages and
  attention runs the paged int8 decode kernel
  (:mod:`repro.kernels.paged_attention`), so the quantized cache is never
  materialized as f32 in HBM.

Tensor-parallel serving: under an active ``mode='serve'`` mesh context
(:func:`repro.parallel.sharding.serve_tp`) with a kv-head count divisible by
the model axis, the paged branches run the **head-sharded shard_map kernel
wrappers** (each device attends over its local heads of its local page
shards; zero KV bytes on the wire) and the output projection runs the
explicit row-parallel path — one (optionally int8-compressed) all-reduce
per attention layer. Indivisible head counts (qwen2-0.5b's 14 over
model=16) degrade gracefully to the replicated single-device path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import paged_attention, paged_attention_tp
from repro.kernels.paged_prefill import (paged_prefill_attention,
                                         paged_prefill_attention_tp)
from repro.models.config import ModelConfig
from repro.models.modules import (apply_rope, linear, rms_norm, rope_freqs,
                                  row_parallel_linear, tp_shardable)
from repro.parallel.sharding import (effective_model_shards, logical,
                                     serve_tp)
from repro.serving.kv_cache import (DEFAULT_PAGE_SIZE, DenseKVCache,
                                    PagedDecodeCache, PagedPrefillCache)

_NEG = -1e30


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * sc).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv * hd)) * sc).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv * hd)) * sc).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * sc).astype(dtype),
    }
    if cfg.qkv_bias:
        p["wq_bias"] = jnp.zeros((h * hd,), dtype)
        p["wk_bias"] = jnp.zeros((kv * hd,), dtype)
        p["wv_bias"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _grouped_attn(q, k, v, q_pos, k_pos, *, k_len: Optional[jax.Array] = None):
    """q: (B,S,KV,G,hd); k,v: (B,T,KV,hd); positions for causal masking.

    ``k_len``: optional valid-length (decode: cache fill level). Returns
    (B,S,KV,G,hd).
    """
    hd = q.shape[-1]
    # bf16 operands, f32 accumulation (MXU semantics) — no f32 copies of q/k
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    mask = q_pos[:, None] >= k_pos[None, :]                      # (S, T) causal
    if k_len is not None:
        mask = mask & (k_pos[None, :] < k_len)
    scores = jnp.where(mask[None, None, None], scores, _NEG)
    # f32 softmax for stability; probs stored bf16 (flash-attention practice)
    # — halves the largest attention buffer.
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.astype(q.dtype)


def attention(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
              *, cache=None, cache_pos: Optional[jax.Array] = None,
              qmode: str = "none"):
    """x: (B, S, D). Returns (y, new_cache).

    * cache None                        → full causal self-attention (train).
    * DenseKVCache, S > 1               → prefill: attend + fill cache[0:S].
    * DenseKVCache, S == 1, cache_pos   → decode: append + attend over prefix.
    * PagedPrefillCache                 → chunked paged prefill: quantize the
      chunk's KV straight into block-table pages, causal flash attention
      over every cached page (no dense KV staging slab). The speculative
      engine's γ+1-token **verify panels** ride this same branch — their
      ``q_start`` resumes mid-page, which the write-once token-granular
      page format makes exact (the panel reads/writes the very bytes
      sequential decode would have).
    * PagedDecodeCache, S == 1          → ragged decode: append to block-table
      pages + paged int8 attention (per-sequence positions, no cache_pos).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv

    q = linear(x, p["wq"], p.get("wq_bias"), qmode=qmode).reshape(b, s, h, hd)
    k = linear(x, p["wk"], p.get("wk_bias"), qmode=qmode).reshape(b, s, kv, hd)
    v = linear(x, p["wv"], p.get("wv_bias"), qmode=qmode).reshape(b, s, kv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    cos, sin = rope_freqs(positions, hd, cfg.rope_theta)         # (B,S,hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = logical(q, "batch", "seq", "heads", "head_dim")
    k = logical(k, "batch", "seq", "kv_heads", "head_dim")
    v = logical(v, "batch", "seq", "kv_heads", "head_dim")

    # head-sharded TP applies when every kv shard holds whole head groups
    mesh, tp = serve_tp()
    head_tp = mesh is not None and effective_model_shards(mesh, kv) > 1

    def _out_proj(out):
        if head_tp and tp_shardable(p["wo"], tp):
            return row_parallel_linear(out, p["wo"], mesh=mesh, qmode=qmode)
        return linear(out, p["wo"], qmode=qmode)

    if isinstance(cache, PagedPrefillCache):
        assert b == 1, "paged prefill runs one sequence's chunk at a time"
        new_cache = cache.write_chunk(jnp.swapaxes(k, 1, 2),
                                      jnp.swapaxes(v, 1, 2))
        qp = jnp.transpose(q.reshape(s, kv, g, hd), (1, 0, 2, 3))
        if head_tp:
            ctx = paged_prefill_attention_tp(
                qp, new_cache.k_pages, new_cache.v_pages, new_cache.k_scale,
                new_cache.v_scale, new_cache.table, mesh=mesh,
                q_start=new_cache.q_start,
                pages_per_step=new_cache.pages_per_step)
        else:
            ctx = paged_prefill_attention(
                qp, new_cache.k_pages, new_cache.v_pages, new_cache.k_scale,
                new_cache.v_scale, new_cache.table, q_start=new_cache.q_start,
                pages_per_step=new_cache.pages_per_step)
        out = jnp.transpose(ctx, (1, 0, 2, 3)).reshape(1, s, h * hd)
        return _out_proj(out), new_cache

    if isinstance(cache, PagedDecodeCache):
        assert s == 1, "paged cache is decode-only (one token per sequence)"
        new_cache = cache.append(jnp.swapaxes(k, 1, 2)[:, :, 0],
                                 jnp.swapaxes(v, 1, 2)[:, :, 0])
        if head_tp:
            ctx = paged_attention_tp(
                q.reshape(b, kv, g, hd), new_cache.k_pages,
                new_cache.v_pages, new_cache.k_scale, new_cache.v_scale,
                new_cache.tables, new_cache.lengths, mesh=mesh)
        else:
            ctx = paged_attention(q.reshape(b, kv, g, hd), new_cache.k_pages,
                                  new_cache.v_pages, new_cache.k_scale,
                                  new_cache.v_scale, new_cache.tables,
                                  new_cache.lengths)
        return _out_proj(ctx.reshape(b, 1, h * hd)), new_cache

    new_cache = None
    if cache is None:
        k_all, v_all, k_pos, k_len = k, v, positions[0], None
    else:
        k_t = jnp.swapaxes(k, 1, 2)                              # (B,KV,S,hd)
        v_t = jnp.swapaxes(v, 1, 2)
        if s > 1:   # prefill from position 0
            new_cache = cache.write_prefill(k_t, v_t)
            k_all, v_all, k_pos, k_len = k, v, positions[0], None
        else:       # decode: append at cache_pos, attend over whole cache
            new_cache = cache.append(k_t, v_t, cache_pos)
            k_all, v_all = new_cache.read(x.dtype)               # (B,T,KV,hd)
            k_pos = jnp.arange(k_all.shape[1])
            k_len = cache_pos + 1

    qg = q.reshape(b, s, kv, g, hd)
    if cache is not None and s == 1:
        q_pos = jnp.full((1,), 0) + cache_pos
        out = _grouped_attn(qg, k_all, v_all, q_pos, k_pos, k_len=k_len)
    elif cfg.attn_q_chunk and s > cfg.attn_q_chunk:
        # exact q-chunked causal attention (python-unrolled)
        nc = s // cfg.attn_q_chunk
        assert s % cfg.attn_q_chunk == 0, (s, cfg.attn_q_chunk)
        k_pos_full = positions[0]
        chunks = []
        for i in range(nc):
            sl = slice(i * cfg.attn_q_chunk, (i + 1) * cfg.attn_q_chunk)
            chunks.append(_grouped_attn(qg[:, sl], k_all, v_all,
                                        k_pos_full[sl], k_pos_full))
        out = jnp.concatenate(chunks, axis=1)
    else:
        q_pos = positions[0]
        out = _grouped_attn(qg, k_all, v_all, q_pos, k_pos)

    out = out.reshape(b, s, h * hd)
    y = linear(out, p["wo"], qmode=qmode)
    return y, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype, *,
               kv_dtype: Optional[str] = None,
               page_size: Optional[int] = None) -> DenseKVCache:
    """Dense slab cache; ``kv_dtype='int8'`` stores KV quantized with
    per-page dynamic scales (see :mod:`repro.serving.kv_cache`)."""
    return DenseKVCache.init(
        batch, cfg.n_kv_heads, max_len, cfg.hd, dtype,
        quantized=(kv_dtype == "int8"),
        page_size=page_size or DEFAULT_PAGE_SIZE)
