"""Model configuration — one dataclass drives all ten assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (defaults to d_ff)
    moe_period: int = 1              # MoE FFN every k-th layer (jamba: 2)
    moe_capacity_factor: float = 1.25

    # layer mixer pattern: 'attn' | 'mamba' | 'rwkv'; cycled over n_layers
    mixer_pattern: Tuple[str, ...] = ("attn",)

    # SSM (mamba) dims
    ssm_expand: int = 2
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_dt_rank: int = 0             # 0 → ceil(d_model/16)
    ssm_seq_chunks: int = 4          # python-unrolled outer segments for scan

    # RWKV6 dims
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 32             # WKV6 chunk length
    rwkv_lora_r: int = 64            # decay/mix LoRA rank

    # modality frontend stub: model consumes precomputed (B, S, d_model)
    # embeddings instead of token ids (pixtral patches / musicgen frames)
    embedding_inputs: bool = False

    dtype: str = "bfloat16"
    remat: bool = True
    attn_q_chunk: int = 0            # q-chunked exact attention (0 = off)
    qmode: str = "none"              # serving quantization (CAMP)
    max_seq_len: int = 8192

    # -- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def mixer_of(self, layer: int) -> str:
        return self.mixer_pattern[layer % len(self.mixer_pattern)]

    def ffn_of(self, layer: int) -> str:
        if self.moe_experts and (layer % self.moe_period == self.moe_period - 1):
            return "moe"
        if self.mixer_of(layer) == "rwkv":
            return "rwkv_cmix"
        return "dense"

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.hd
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            mixer = self.mixer_of(i)
            if mixer == "attn":
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)  # qkv
                total += self.n_heads * hd * d                           # wo
                if self.qkv_bias:
                    total += hd * (self.n_heads + 2 * self.n_kv_heads)
            elif mixer == "mamba":
                di, N, r = self.d_inner, self.ssm_state_dim, self.dt_rank
                total += d * 2 * di + di * self.ssm_conv_dim
                total += di * (r + 2 * N) + r * di + di * N + 2 * di
                total += di * d
            elif mixer == "rwkv":
                total += 4 * d * d + d * d       # r,k,v,gate + out
                total += 2 * (d * self.rwkv_lora_r * 2)  # decay/mix LoRAs
            ffn = self.ffn_of(i)
            if ffn == "dense":
                total += 3 * d * self.d_ff
            elif ffn == "moe":
                total += d * self.moe_experts
                total += self.moe_experts * 3 * d * self.expert_ff
            elif ffn == "rwkv_cmix":
                total += 2 * d * self.d_ff // 2 + d * self.d_ff  # k,v,r
            total += 2 * d                       # norms
        total += d                               # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.moe_experts:
            return self.param_count()
        d = self.d_model
        dense_like = dataclasses.replace(self, moe_experts=0, moe_top_k=0)
        base = dense_like.param_count()
        # remove the dense FFNs that MoE layers replace, add k experts + router
        n_moe = sum(1 for i in range(self.n_layers) if self.ffn_of(i) == "moe")
        base -= n_moe * 3 * d * self.d_ff
        base += n_moe * (d * self.moe_experts
                         + self.moe_top_k * 3 * d * self.expert_ff)
        return base
