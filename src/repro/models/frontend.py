"""Modality-frontend STUBS (per assignment: backbone-only for [vlm]/[audio]).

pixtral-12b's ViT patch encoder and musicgen-large's EnCodec tokenizer are not
part of the assigned backbone; ``input_specs()`` for those architectures
provides *precomputed* patch/frame embeddings of shape (B, S, d_model). These
helpers generate synthetic stand-ins for tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def synth_patch_embeddings(key, cfg: ModelConfig, batch: int, seq: int):
    """Stand-in for a ViT patch encoder output (pixtral)."""
    return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.bfloat16)


def synth_frame_embeddings(key, cfg: ModelConfig, batch: int, seq: int):
    """Stand-in for EnCodec frame embeddings (musicgen)."""
    return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.bfloat16)


def input_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.embedding_inputs else jnp.int32


def input_shape(cfg: ModelConfig, batch: int, seq: int):
    if cfg.embedding_inputs:
        return (batch, seq, cfg.d_model)
    return (batch, seq)
