"""Shared building blocks: norms, rope, linear-with-CAMP, gated MLP."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.camp import camp_matmul
from repro.core.quant import QuantizedTensor
from repro.parallel.sharding import logical


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    # variance reduced in f32, but no (B,S,D) f32 materialization: the only
    # f32 tensor is the (B,S,1) variance (the f32 upcast of x itself would be
    # a multi-GiB live buffer at 32k prefill).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def group_norm_heads(x: jax.Array, scale: jax.Array, bias: jax.Array,
                     eps: float = 1e-5) -> jax.Array:
    """Per-head LayerNorm over the last dim. x: (..., H, hd)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def linear(x: jax.Array, w, bias: Optional[jax.Array] = None, *,
           qmode: str = "none", impl: str = "auto") -> jax.Array:
    """``x @ W (+ b)`` — dispatches to the CAMP quantized pipeline when the
    weight is a :class:`QuantizedTensor`."""
    if isinstance(w, QuantizedTensor):
        y = camp_matmul(x, w, qmode=(qmode if qmode != "none" else "w8a8"),
                        impl=impl)
    else:
        y = jnp.matmul(x, w.astype(x.dtype))
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions: (...,) int → (cos, sin) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) → rotated x (half-split)."""
    x32 = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x32[..., :half], x32[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


def gated_mlp(x: jax.Array, p: dict, *, qmode: str = "none") -> jax.Array:
    """SiLU-gated FFN (llama-style): down(silu(gate(x)) * up(x))."""
    g = linear(x, p["w_gate"], qmode=qmode)
    u = linear(x, p["w_up"], qmode=qmode)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = logical(h, "batch", "seq", "d_ff")
    return linear(h, p["w_down"], qmode=qmode)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross entropy; logits (B,S,V) f32-cast, labels (B,S)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_xent(h: jax.Array, head, labels: jax.Array, *,
                 n_chunks: int = 8) -> jax.Array:
    """Streamed cross-entropy: never materializes the (B,S,V) logits.

    The (B,S,V) f32 logits (2.5 GiB/device at gb=256×4k with a 152k vocab)
    and the matching f32 lm_head-gradient buffers are the largest training
    allocations. Chunking the vocab with an online logsumexp and `remat`
    around each chunk bounds live memory to one (B,S,V/n) slice — the
    standard fused-xent production trick. Exact (online max-normalized).

    h: (B,S,D) final hidden; head: (D,V) weight (or QuantizedTensor).
    """
    from repro.core.quant import QuantizedTensor
    if isinstance(head, QuantizedTensor):
        head = head.dequantize()
    b, s, d = h.shape
    v = head.shape[-1]
    while v % n_chunks:
        n_chunks -= 1
    vc = v // n_chunks

    def chunk_stats(h_, head_c, labels_, c0):
        logits = jnp.matmul(h_, head_c.astype(h_.dtype)).astype(jnp.float32)
        m = jnp.max(logits, axis=-1)                        # (B,S)
        s_ = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
        idx = labels_ - c0
        in_c = (idx >= 0) & (idx < vc)
        gold = jnp.take_along_axis(
            logits, jnp.clip(idx, 0, vc - 1)[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_c, gold, 0.0)
        return m, s_, gold

    chunk_stats = jax.checkpoint(chunk_stats, static_argnums=())

    run_m = jnp.full((b, s), -jnp.inf, jnp.float32)
    run_s = jnp.zeros((b, s), jnp.float32)
    gold_total = jnp.zeros((b, s), jnp.float32)
    for c in range(n_chunks):
        head_c = jax.lax.dynamic_slice_in_dim(head, c * vc, vc, axis=1)
        m, s_, gold = chunk_stats(h, head_c, labels, c * vc)
        new_m = jnp.maximum(run_m, m)
        run_s = run_s * jnp.exp(run_m - new_m) + s_ * jnp.exp(m - new_m)
        run_m = new_m
        gold_total = gold_total + gold
    lse = run_m + jnp.log(run_s)
    return jnp.mean(lse - gold_total)
