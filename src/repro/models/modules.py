"""Shared building blocks: norms, rope, linear-with-CAMP, gated MLP.

Tensor-parallel serving: :func:`row_parallel_linear` is the explicit
shard_map call path for the two row-parallel projections of a transformer
block (attention ``wo``, MLP ``w_down``). Each device runs the fused CAMP
GEMM on its K-shard of the weight and the matching slice of the activation,
then the partial outputs are all-reduced — optionally with an int8 payload
on the wire (:func:`repro.parallel.collectives.quantized_psum`). Under an
active ``mode='serve'`` mesh context :func:`gated_mlp` and the attention
output projection route through it automatically when the sharded dim
divides the model axis; otherwise they fall back to the replicated path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.camp import camp_matmul, weight_bits
from repro.core.quant import QuantizedTensor
from repro.kernels.epilogue import apply_epilogue, parse_epilogue
from repro.parallel.collectives import quantized_psum
from repro.parallel.sharding import active_ctx, logical, serve_tp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    # variance reduced in f32, but no (B,S,D) f32 materialization: the only
    # f32 tensor is the (B,S,1) variance (the f32 upcast of x itself would be
    # a multi-GiB live buffer at 32k prefill).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def group_norm_heads(x: jax.Array, scale: jax.Array, bias: jax.Array,
                     eps: float = 1e-5) -> jax.Array:
    """Per-head LayerNorm over the last dim. x: (..., H, hd)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def linear(x: jax.Array, w, bias: Optional[jax.Array] = None, *,
           qmode: str = "none", impl: str = "auto",
           epilogue: Optional[str] = None,
           operand: Optional[jax.Array] = None) -> jax.Array:
    """``x @ W (+ b)`` — dispatches to the CAMP quantized pipeline when the
    weight is a :class:`QuantizedTensor`.

    ``epilogue`` appends fused tail stages after the bias (e.g. ``'silu'``,
    ``'gelu'``, ``'mul'``/``'residual'`` with ``operand``); on the quantized
    path they run inside the kernel flush on the f32 accumulator, so the
    activation never round-trips through HBM as a standalone elementwise op.
    """
    stages = []
    if bias is not None:
        stages.append("bias")
    if epilogue and epilogue != "none":
        stages.append(epilogue)
    epi = "+".join(stages) if stages else "none"
    if isinstance(w, QuantizedTensor):
        # The weight's payload decides the kernel family: a caller-side qmode
        # of 'none' (or one whose weight bits disagree with the stored
        # payload, e.g. params quantized separately from cfg.qmode) is
        # remapped to the mode matching the weight — keeping the requested
        # activation treatment (weight-only stays weight-only).
        if qmode == "none" or weight_bits(qmode) != w.bits:
            if qmode.endswith("a16"):
                qmode = "w8a16" if w.bits == 8 else "w4a16"
            else:
                qmode = "w8a8" if w.bits == 8 else "w4a8"
        return camp_matmul(x, w, qmode=qmode, impl=impl, epilogue=epi,
                           bias=bias, operand=operand)
    y = jnp.matmul(x, w.astype(x.dtype))
    if epi != "none":
        y = apply_epilogue(
            y.astype(jnp.float32), parse_epilogue(epi),
            bias=None if bias is None else bias.reshape(1, -1),
            operand=operand).astype(x.dtype)
    return y


def tp_shardable(w, tp: int) -> bool:
    """Can a (K, N) weight's contraction dim split over ``tp`` shards?

    int4 payloads are packed 2-per-byte along K, so each K-shard must also
    hold an even number of logical rows.
    """
    if tp <= 1:
        return False
    k = w.shape[0]
    if k % tp:
        return False
    if isinstance(w, QuantizedTensor) and w.bits == 4:
        return (k // tp) % 2 == 0
    return True


def _tp_int8_reduce() -> bool:
    ctx = active_ctx()
    return bool(ctx is not None and ctx.opts.get("tp_int8_reduce"))


def row_parallel_linear(x: jax.Array, w, *, mesh, axis: str = "model",
                        qmode: str = "none", impl: str = "auto",
                        quantized_reduce: Optional[bool] = None) -> jax.Array:
    """Megatron row-parallel projection: ``x @ W`` with W K-sharded.

    ``x``: (..., K) with the last dim carried by ``axis`` (attention heads ×
    head_dim after head-sharded attention; d_ff after column-parallel
    gate/up); ``w``: (K, N) row-sharded on the same axis. Each device runs
    the fused CAMP GEMM (or bf16 matmul) on its local shard — the activation
    quantization inside the kernel sees only shard-local rows, so no
    quantized operand is ever gathered — and the f32 partial outputs are
    all-reduced, int8-compressed on the wire when ``quantized_reduce``
    (default: the serve context's ``tp_int8_reduce`` opt).
    """
    if quantized_reduce is None:
        quantized_reduce = _tp_int8_reduce()
    xspec = P(*((None,) * (x.ndim - 1) + (axis,)))
    yspec = P(*((None,) * x.ndim))

    def reduce(y):
        y = y.astype(jnp.float32)
        return quantized_psum(y, axis) if quantized_reduce \
            else jax.lax.psum(y, axis)

    if isinstance(w, QuantizedTensor):
        n = w.shape[1]
        bits = w.bits

        def body(x_l, wq_l, ws_l):
            w_l = QuantizedTensor(q=wq_l, scale=ws_l, bits=bits,
                                  shape=(x_l.shape[-1], n))
            return reduce(linear(x_l, w_l, qmode=qmode, impl=impl))

        fn = shard_map(body, mesh=mesh,
                       in_specs=(xspec, P(axis, None), P(None, None)),
                       out_specs=yspec, check_rep=False)
        return fn(x, w.q, w.scale).astype(x.dtype)

    def body(x_l, w_l):
        return reduce(jnp.matmul(x_l, w_l.astype(x_l.dtype)))

    fn = shard_map(body, mesh=mesh, in_specs=(xspec, P(axis, None)),
                   out_specs=yspec, check_rep=False)
    return fn(x, w).astype(x.dtype)


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions: (...,) int → (cos, sin) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) → rotated x (half-split)."""
    x32 = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x32[..., :half], x32[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


def gated_mlp(x: jax.Array, p: dict, *, qmode: str = "none") -> jax.Array:
    """SiLU-gated FFN (llama-style): down(silu(gate(x)) * up(x)).

    Three fused kernel calls, zero standalone elementwise ops: the gate
    projection applies SiLU in its flush, the up projection multiplies by the
    activated gate in *its* flush, and the down projection is plain. Under a
    serve-mode mesh the gate/up projections are column-parallel (weights
    d_ff-sharded via the logical rules) and the down projection runs the
    explicit row-parallel shard_map path — one all-reduce per MLP.
    """
    g = linear(x, p["w_gate"], qmode=qmode, epilogue="silu")
    h = linear(x, p["w_up"], qmode=qmode, epilogue="mul", operand=g)
    h = logical(h, "batch", "seq", "d_ff")
    mesh, tp = serve_tp()
    if mesh is not None and tp_shardable(p["w_down"], tp):
        return row_parallel_linear(h, p["w_down"], mesh=mesh, qmode=qmode)
    return linear(h, p["w_down"], qmode=qmode)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross entropy; logits (B,S,V) f32-cast, labels (B,S)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_xent(h: jax.Array, head, labels: jax.Array, *,
                 n_chunks: int = 8) -> jax.Array:
    """Streamed cross-entropy: never materializes the (B,S,V) logits.

    The (B,S,V) f32 logits (2.5 GiB/device at gb=256×4k with a 152k vocab)
    and the matching f32 lm_head-gradient buffers are the largest training
    allocations. Chunking the vocab with an online logsumexp and `remat`
    around each chunk bounds live memory to one (B,S,V/n) slice — the
    standard fused-xent production trick. Exact (online max-normalized).

    h: (B,S,D) final hidden; head: (D,V) weight (or QuantizedTensor).
    """
    from repro.core.quant import QuantizedTensor
    if isinstance(head, QuantizedTensor):
        head = head.dequantize()
    b, s, d = h.shape
    v = head.shape[-1]
    while v % n_chunks:
        n_chunks -= 1
    vc = v // n_chunks

    def chunk_stats(h_, head_c, labels_, c0):
        logits = jnp.matmul(h_, head_c.astype(h_.dtype)).astype(jnp.float32)
        m = jnp.max(logits, axis=-1)                        # (B,S)
        s_ = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
        idx = labels_ - c0
        in_c = (idx >= 0) & (idx < vc)
        gold = jnp.take_along_axis(
            logits, jnp.clip(idx, 0, vc - 1)[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_c, gold, 0.0)
        return m, s_, gold

    chunk_stats = jax.checkpoint(chunk_stats, static_argnums=())

    run_m = jnp.full((b, s), -jnp.inf, jnp.float32)
    run_s = jnp.zeros((b, s), jnp.float32)
    gold_total = jnp.zeros((b, s), jnp.float32)
    for c in range(n_chunks):
        head_c = jax.lax.dynamic_slice_in_dim(head, c * vc, vc, axis=1)
        m, s_, gold = chunk_stats(h, head_c, labels, c * vc)
        new_m = jnp.maximum(run_m, m)
        run_s = run_s * jnp.exp(run_m - new_m) + s_ * jnp.exp(m - new_m)
        run_m = new_m
        gold_total = gold_total + gold
    lse = run_m + jnp.log(run_s)
    return jnp.mean(lse - gold_total)
