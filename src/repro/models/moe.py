"""Mixture-of-Experts FFN: top-k router with capacity, gather/scatter dispatch.

Dispatch is **slot-indexed** (Megablocks/T5X-style), not GShard one-hot
einsums: a one-hot dispatch einsum costs 2·T·S_g·k·cf·D FLOPs — at the
assigned train_4k shape (1M tokens) that is ~100× the expert matmul FLOPs.
Here routing builds an (expert, slot) → token index map with cumsum + scatter
(O(T·E·k) integer ops), dispatch/combine are gathers (zero FLOPs), and all
GEMM FLOPs are the real expert compute: 2 · (T·k·cf) · D · F per projection.

Sharding: groups (G) carry the data axis, experts (E) the model axis. Under
GSPMD the combine-gather of the (G,E,C,D) expert outputs becomes the MoE
all-to-all/all-gather — visible in the dry-run collective schedule.

Quantized serving path (CAMP): per-expert int8/int4 GEMMs dispatched through
the **fused CAMP kernel family** (:mod:`repro.kernels.ops`) — activation
quantization happens inside each expert's GEMM, block sizes come from the
persistent autotune cache (the expert shapes
``serving.engine.warm_gemm_autotune`` pre-tunes), and the Cartesian
(expert, row) × (expert, col) scale epilogue is the 3-D generalization of
the paper's kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import QuantizedTensor, quantize_colwise, pack_int4
from repro.models.config import ModelConfig
from repro.parallel.sharding import logical

MOE_MIN_CAPACITY = 8
MOE_GROUP_SIZE = 4096  # tokens per routing group


def routing_group_size(n_tokens: int) -> int:
    """Largest group size ≤ MOE_GROUP_SIZE that divides ``n_tokens``
    (shared with the autotune warmup so pre-tuned expert GEMM shapes match
    the served ones)."""
    sg = min(MOE_GROUP_SIZE, n_tokens)
    while n_tokens % sg:
        sg //= 2
    return sg


def expert_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    """Expert slot count for one routing group (shared with the autotune
    warmup so pre-tuned expert GEMM shapes match the served ones)."""
    cap = max(MOE_MIN_CAPACITY,
              int((tokens_per_group * cfg.moe_top_k * cfg.moe_capacity_factor)
                  / cfg.moe_experts))
    return min(-(-cap // 4) * 4, tokens_per_group * cfg.moe_top_k)


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.expert_ff
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * sc).astype(jnp.float32),
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (e, d, f)) * sc).astype(dtype),
            "w_up": (jax.random.normal(ks[2], (e, d, f)) * sc).astype(dtype),
            "w_down": (jax.random.normal(ks[3], (e, f, d)) * (f ** -0.5)).astype(dtype),
        },
    }


def quantize_expert_weight(w: jax.Array, bits: int) -> QuantizedTensor:
    """(E, K, N) → per-expert per-output-channel quantization, packed on K."""
    q, scale = jax.vmap(lambda m: quantize_colwise(m, bits))(w)   # (E,K,N),(E,1,N)
    if bits == 4:
        q = jax.vmap(pack_int4)(q)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32), bits=bits,
                           shape=tuple(w.shape))


def _dequant_expert(w: QuantizedTensor) -> jax.Array:
    from repro.core.quant import unpack_int4
    q = w.q if w.bits == 8 else jax.vmap(lambda m: unpack_int4(m))(w.q)
    return q.astype(w.scale.dtype) * w.scale


def _expert_matmul(xe: jax.Array, w, qmode: str) -> jax.Array:
    """Batched per-expert GEMM: (..., E, C, K) × (E, K, N) → (..., E, C, N).

    Integer modes dispatch each expert through the **fused CAMP GEMM
    family** (``ops.gemm_*_fused``): activations quantize inside the kernel
    (the int8/int4 payload and row scales never exist in HBM) and block
    sizes come from the persistent autotune cache — the expert shapes
    ``warm_gemm_autotune`` pre-populates — instead of a hardcoded triple.
    """
    if not isinstance(w, QuantizedTensor):
        return jnp.einsum("...eck,ekn->...ecn", xe, w.astype(xe.dtype))
    if qmode in ("w8a16", "w4a16", "none"):
        wd = _dequant_expert(w)
        return jnp.einsum("...eck,ekn->...ecn", xe, wd.astype(xe.dtype))
    # integer path: per-expert fused quantize+GEMM (python-unrolled over E —
    # each expert is one CAMP kernel launch with its own tuned blocks)
    from repro.kernels import ops
    lead = xe.shape[:-3]
    e, c, kk = xe.shape[-3:]
    x2 = jnp.moveaxis(xe.reshape((-1,) + (e, c, kk)), 0, 1)       # (E,L,C,K)
    x2 = x2.reshape(e, -1, kk)                                    # (E,L*C,K)
    if w.bits == 8:
        gemm = ops.gemm_i8_fused
    elif qmode == "w4a4":
        gemm = ops.gemm_a4w4_fused
    else:
        gemm = ops.gemm_w4_fused
    outs = [gemm(x2[ei], w.q[ei], w.scale[ei], out_dtype=jnp.float32)
            for ei in range(e)]
    acc = jnp.stack(outs)                                         # (E,L*C,N)
    n = acc.shape[-1]
    acc = jnp.moveaxis(acc.reshape(e, -1, c, n), 1, 0).reshape(lead + (e, c, n))
    return acc.astype(xe.dtype)


def _route(gates: jax.Array, k: int, cap: int):
    """gates: (G, S, E) f32. Returns (slots (G,S,k) int32 in [0, E*cap],
    weights (G,S,k) f32). Slot E*cap is the overflow sentinel."""
    g, s, e = gates.shape
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)
    counts = jnp.zeros((g, e), jnp.int32)
    slots = []
    for j in range(k):
        oh = jax.nn.one_hot(topi[:, :, j], e, dtype=jnp.int32)      # (G,S,E)
        pos_all = jnp.cumsum(oh, axis=1) - 1 + counts[:, None]      # (G,S,E)
        pos = jnp.take_along_axis(pos_all, topi[:, :, j:j + 1], axis=-1)[..., 0]
        counts = counts + oh.sum(axis=1)
        ok = pos < cap
        slot = jnp.where(ok, topi[:, :, j] * cap + pos, e * cap)
        slots.append(slot)
    return jnp.stack(slots, axis=-1), topv


def moe_ffn(p: dict, cfg: ModelConfig, x: jax.Array, *, qmode: str = "none"):
    """x: (B, S, D) → (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    t = b * s
    sg = routing_group_size(t)
    g = t // sg
    cap = expert_capacity(sg, cfg)

    xg = x.reshape(g, sg, d)
    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    slots, weights = _route(gates, k, cap)                          # (G,S,k)

    # slot → token map (scatter); sentinel token index = sg (zero row)
    tok_ids = jnp.broadcast_to(jnp.arange(sg, dtype=jnp.int32)[None, :, None],
                               slots.shape)
    g_ids = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[:, None, None],
                             slots.shape)
    tok_for_slot = jnp.full((g, e * cap + 1), sg, jnp.int32)
    tok_for_slot = tok_for_slot.at[g_ids.reshape(-1), slots.reshape(-1)].set(
        tok_ids.reshape(-1), mode="drop")

    # dispatch: gather tokens into (G, E, C, D)
    xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad, tok_for_slot[:, :e * cap, None], axis=1).reshape(g, e, cap, d)
    xe = logical(xe, "moe_group", "expert", "moe_capacity", "embed")

    # expert GEMMs — the real FLOPs. Under the serve-mode rule table the
    # expert_ff dim carries the model axis (tensor-parallel experts: gate/up
    # column-parallel, down row-parallel with GSPMD placing the all-reduce)
    # while experts stay expert-parallel over data for training/prefill.
    gate = _expert_matmul(xe, p["experts"]["w_gate"], qmode)
    gate = logical(gate, "moe_group", "expert", "moe_capacity", "expert_ff")
    up = _expert_matmul(xe, p["experts"]["w_up"], qmode)
    up = logical(up, "moe_group", "expert", "moe_capacity", "expert_ff")
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = logical(h, "moe_group", "expert", "moe_capacity", "expert_ff")
    ye = _expert_matmul(h, p["experts"]["w_down"], qmode)
    ye = logical(ye, "moe_group", "expert", "moe_capacity", "embed")

    # combine: gather each token's k expert outputs, weight, sum
    ye_flat = ye.reshape(g, e * cap, d)
    ye_pad = jnp.concatenate([ye_flat, jnp.zeros((g, 1, d), ye.dtype)], axis=1)
    picked = jnp.take_along_axis(
        ye_pad, slots.reshape(g, sg * k)[:, :, None], axis=1)
    picked = picked.reshape(g, sg, k, d).astype(jnp.float32)
    y = jnp.einsum("gskd,gsk->gsd", picked, weights).astype(x.dtype)

    # load-balance aux (Switch): E · Σ_e fraction_e · mean_gate_e
    top1 = jax.nn.one_hot(jnp.argmax(gates, -1), e, dtype=jnp.float32)
    aux = e * jnp.sum(jnp.mean(top1.reshape(t, e), axis=0)
                      * jnp.mean(gates.reshape(t, e), axis=0))
    return y.reshape(b, s, d), aux
