"""RWKV6 "Finch" mixer — data-dependent decay linear attention, chunkwise.

The WKV6 recurrence per head (state S ∈ R^{hd_k × hd_v}):

    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ
    y_t = r_tᵀ · (S_{t-1} + diag(u) k_t v_tᵀ)

is evaluated in **chunkwise-parallel** form: all intra-chunk work is batched
einsums over every chunk at once (counted by the dry-run cost analysis); only
the (negligible-FLOP) cross-chunk state propagation is a `lax.scan`.

Numerical scheme: with log-decays `lw = log w_t ∈ [-LW_MAX, -1e-4]` clamped
and chunk length C, the factorized intra-chunk matrix

    A[t,s] = Σ_k r_tk · k_sk · exp(cl_{t-1,k} − cl_{s,k})   (s < t)

is computed as (r ⊙ exp(cl_prev − CL)) @ (k ⊙ exp(CL − cl))ᵀ. Both exponents
are bounded by |CL| ≤ C·LW_MAX; with C=32 and LW_MAX=2.5 that is 80 < 88 =
log(f32max), so no overflow/underflow. The decay floor exp(-2.5)/step is a
documented design choice of this from-scratch implementation; the chunked
path is tested bit-close against the sequential oracle under the same clamp.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.modules import group_norm_heads, linear
from repro.parallel.sharding import logical

LW_MAX = 2.5
_MIX = ("r", "w", "k", "v", "g")


def init_rwkv_time_mix(key, cfg: ModelConfig, dtype) -> dict:
    d, hd, r = cfg.d_model, cfg.rwkv_head_dim, cfg.rwkv_lora_r
    h = d // hd
    ks = jax.random.split(key, 8)
    sc = d ** -0.5
    kproj = jax.random.split(ks[0], 4)
    p = {
        "wr": (jax.random.normal(kproj[0], (d, d)) * sc).astype(dtype),
        "wk": (jax.random.normal(kproj[1], (d, d)) * sc).astype(dtype),
        "wv": (jax.random.normal(kproj[2], (d, d)) * sc).astype(dtype),
        "wg": (jax.random.normal(kproj[3], (d, d)) * sc).astype(dtype),
        "out_proj": (jax.random.normal(ks[1], (d, d)) * sc).astype(dtype),
        "time_maa_x": jnp.zeros((d,), dtype),
        "time_maa": jnp.zeros((len(_MIX), d), dtype),
        "time_maa_w1": (jax.random.normal(ks[2], (d, len(_MIX) * 32)) * sc).astype(dtype),
        "time_maa_w2": (jax.random.normal(ks[3], (len(_MIX), 32, d)) * 0.03).astype(dtype),
        "w0": jnp.full((d,), 0.5, dtype),             # base log-log decay
        "w_lora_a": (jax.random.normal(ks[4], (d, r)) * sc).astype(dtype),
        "w_lora_b": (jax.random.normal(ks[5], (r, d)) * 0.03).astype(dtype),
        "u": (jax.random.normal(ks[6], (h, hd)) * 0.1).astype(dtype),
        "g_norm_scale": jnp.ones((h, hd), dtype),
        "g_norm_bias": jnp.zeros((h, hd), dtype),
    }
    return p


def _ddlerp(p, x, x_prev):
    """RWKV6 data-dependent token-shift interpolation for the 5 streams."""
    sx = x_prev - x                                            # (B,S,D)
    xxx = x + sx * p["time_maa_x"].astype(x.dtype)
    lora = jnp.tanh(linear(xxx, p["time_maa_w1"]))             # (B,S,5*32)
    b, s, _ = lora.shape
    lora = lora.reshape(b, s, len(_MIX), 32)
    dd = jnp.einsum("bsmr,mrd->bsmd", lora, p["time_maa_w2"].astype(x.dtype))
    mixed = {}
    for i, name in enumerate(_MIX):
        maa = p["time_maa"][i].astype(x.dtype) + dd[:, :, i]
        mixed[name] = x + sx * maa
    return mixed


def _wkv6_chunked(r, k, v, lw, u, s0, chunk: int):
    """Chunkwise-parallel WKV6. r,k,v,lw: (B,S,H,hd) f32 (lw = log decay ≤ 0),
    u: (H,hd), s0: (B,H,hd,hd). Returns (y (B,S,H,hd), s_final)."""
    b, s, h, hd = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rs = r.reshape(b, nc, chunk, h, hd)
    ks_ = k.reshape(b, nc, chunk, h, hd)
    vs = v.reshape(b, nc, chunk, h, hd)
    lws = lw.reshape(b, nc, chunk, h, hd)

    cl = jnp.cumsum(lws, axis=2)                               # inclusive Σlw
    cl_prev = cl - lws                                         # exclusive
    CL = cl[:, :, -1:]                                         # (B,nc,1,H,hd)

    q_t = rs * jnp.exp(cl_prev - CL)                           # bounded ≤ e^{|CL|}
    k_t = ks_ * jnp.exp(CL - cl)                               # bounded ≤ 1
    # strictly-causal intra-chunk attention matrix (B,nc,H,C,C)
    a = jnp.einsum("bnthd,bnshd->bnhts", q_t, k_t)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    a = jnp.where(tri[None, None, None], a, 0.0)
    y_intra = jnp.einsum("bnhts,bnshd->bnthd", a, vs)
    # diagonal (current-token bonus) term
    y_diag = jnp.einsum("bnthd,bnthd->bnth", rs * u[None, None, None], ks_)
    y_intra = y_intra + y_diag[..., None] * vs

    # cross-chunk: per-chunk state inputs and decays (parallel einsums)
    upd = jnp.einsum("bnshd,bnshe->bnhde", k_t, vs)            # Σ k̃ ⊗ v
    dec = jnp.exp(CL[:, :, 0])                                 # (B,nc,H,hd)

    def step(s_in, inp):
        dec_i, upd_i = inp
        s_out = s_in * dec_i[..., None] + upd_i
        return s_out, s_in                                     # emit state *before* chunk
    (s_fin, s_starts) = jax.lax.scan(
        step, s0, (jnp.moveaxis(dec, 1, 0), jnp.moveaxis(upd, 1, 0)))
    s_starts = jnp.moveaxis(s_starts, 0, 1)                    # (B,nc,H,hd,hd)

    q_c = rs * jnp.exp(cl_prev)                                # decay from chunk start
    y_cross = jnp.einsum("bnthd,bnhde->bnthe", q_c, s_starts)
    y = (y_intra + y_cross).reshape(b, s, h, hd)
    return y, s_fin


def _wkv6_step(r, k, v, lw, u, s0):
    """Single-token WKV6 (decode). r,k,v,lw: (B,1,H,hd) f32."""
    r0, k0, v0, lw0 = (t[:, 0] for t in (r, k, v, lw))
    y = jnp.einsum("bhd,bhde->bhe", r0, s0) \
        + jnp.einsum("bhd,bhd->bh", r0 * u[None], k0)[..., None] * v0
    s1 = s0 * jnp.exp(lw0)[..., None] + k0[..., None] * v0[:, :, None]
    return y[:, None], s1


def rwkv_time_mix(p: dict, cfg: ModelConfig, x: jax.Array, *,
                  cache: Optional[dict] = None, qmode: str = "none"):
    """x: (B,S,D) → (y, new_cache). cache = {'s': (B,H,hd,hd) f32,
    'x_prev': (B,D)}."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd

    if cache is not None:
        x_prev_tok = cache["x_prev"][:, None]
    else:
        x_prev_tok = jnp.zeros((b, 1, d), x.dtype)
    x_shift = jnp.concatenate([x_prev_tok, x[:, :-1]], axis=1)
    mixed = _ddlerp(p, x, x_shift)

    r = linear(mixed["r"], p["wr"], qmode=qmode)
    k = linear(mixed["k"], p["wk"], qmode=qmode)
    v = linear(mixed["v"], p["wv"], qmode=qmode)
    g = jax.nn.silu(linear(mixed["g"], p["wg"], qmode=qmode).astype(jnp.float32)).astype(x.dtype)

    lw_raw = p["w0"].astype(jnp.float32) + jnp.tanh(
        linear(mixed["w"], p["w_lora_a"]).astype(jnp.float32)
    ) @ p["w_lora_b"].astype(jnp.float32)
    lw = -jnp.clip(jnp.exp(lw_raw), 1e-4, LW_MAX)              # (B,S,D), ≤ 0

    rh = r.reshape(b, s, h, hd).astype(jnp.float32)
    kh = k.reshape(b, s, h, hd).astype(jnp.float32)
    vh = v.reshape(b, s, h, hd).astype(jnp.float32)
    lwh = lw.reshape(b, s, h, hd)
    rh = logical(rh, "batch", "seq", "heads", "head_dim")
    kh = logical(kh, "batch", "seq", "heads", "head_dim")
    vh = logical(vh, "batch", "seq", "heads", "head_dim")

    s0 = (cache["s"] if cache is not None
          else jnp.zeros((b, h, hd, hd), jnp.float32))
    u = p["u"].astype(jnp.float32)

    if s == 1:
        y, s_fin = _wkv6_step(rh, kh, vh, lwh, u, s0)
    else:
        chunk = min(cfg.rwkv_chunk, s)
        while s % chunk:
            chunk -= 1
        y, s_fin = _wkv6_chunked(rh, kh, vh, lwh, u, s0, chunk)

    y = group_norm_heads(y, p["g_norm_scale"], p["g_norm_bias"], cfg.norm_eps)
    y = (y.reshape(b, s, d).astype(x.dtype)) * g
    out = linear(y, p["out_proj"], qmode=qmode)

    new_cache = None
    if cache is not None:
        new_cache = {"s": s_fin, "x_prev": x[:, -1]}
    return out, new_cache


def wkv6_sequential_ref(r, k, v, lw, u, s0):
    """Sequential oracle for the chunked WKV6 (testing only)."""
    b, s, h, hd = r.shape
    ys = []
    st = s0
    for t in range(s):
        y = jnp.einsum("bhd,bhde->bhe", r[:, t], st) \
            + jnp.einsum("bhd,bhd->bh", r[:, t] * u[None], k[:, t])[..., None] * v[:, t]
        st = st * jnp.exp(lw[:, t])[..., None] + k[:, t][..., None] * v[:, t][:, :, None]
        ys.append(y)
    return jnp.stack(ys, axis=1), st


# ---------------------------------------------------------------------------
# RWKV channel-mix (the FFN analogue)
# ---------------------------------------------------------------------------
def init_rwkv_channel_mix(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "maa_k": jnp.zeros((d,), dtype),
        "maa_r": jnp.zeros((d,), dtype),
        "w_gate": (jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[1], (f, d)) * f ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (d, d)) * d ** -0.5).astype(dtype),  # receptance
    }


def rwkv_channel_mix(p: dict, cfg: ModelConfig, x: jax.Array, *,
                     cache: Optional[dict] = None, qmode: str = "none"):
    b, s, d = x.shape
    if cache is not None:
        x_prev_tok = cache["x_prev"][:, None]
    else:
        x_prev_tok = jnp.zeros((b, 1, d), x.dtype)
    x_shift = jnp.concatenate([x_prev_tok, x[:, :-1]], axis=1)
    sx = x_shift - x
    xk = x + sx * p["maa_k"].astype(x.dtype)
    xr = x + sx * p["maa_r"].astype(x.dtype)
    k = linear(xk, p["w_gate"], qmode=qmode)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = logical(k, "batch", "seq", "d_ff")
    v = linear(k, p["w_down"], qmode=qmode)
    rgate = jax.nn.sigmoid(linear(xr, p["w_up"], qmode=qmode).astype(jnp.float32))
    y = (rgate * v.astype(jnp.float32)).astype(x.dtype)
    new_cache = {"x_prev": x[:, -1]} if cache is not None else None
    return y, new_cache
