"""Mamba (S6) selective-SSM mixer — Jamba's recurrent layer.

Training/prefill uses a **parallel associative scan** over time (log-depth,
all FLOPs visible to the dry-run cost analysis), python-segmented into
``cfg.ssm_seq_chunks`` pieces so the (B, S, d_inner, N) scan intermediates
never exceed one segment. Decode is the O(1) single-step recurrence — this is
what makes the 500k-context cell for hybrid archs trivial at serve time.

The CAMP technique applies to this layer's GEMMs (in/x/dt/out projections);
the recurrence itself is elementwise and stays in f32 (noted in DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.modules import linear
from repro.parallel.sharding import logical


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d, di, n, r, cw = (cfg.d_model, cfg.d_inner, cfg.ssm_state_dim,
                       cfg.dt_rank, cfg.ssm_conv_dim)
    ks = jax.random.split(key, 6)
    sc = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cw, di)) * (cw ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[2], (di, r + 2 * n)) * (di ** -0.5)).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (r, di)) * (r ** -0.5)).astype(dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),      # softplus ≈ 0.01 init
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * (di ** -0.5)).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None):
    """Depthwise causal conv over time. x: (B,S,di), w: (cw,di).

    ``prev``: (B, cw-1, di) trailing inputs from the previous segment/step.
    Returns (y, new_prev).
    """
    cw = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_prev = xp[:, xp.shape[1] - (cw - 1):]
    return y + b, new_prev


def _ssm_scan_segment(a: jax.Array, bu: jax.Array, h0: jax.Array):
    """h_t = a_t ⊙ h_{t-1} + bu_t over axis 1. a, bu: (B,Sseg,di,N) f32.

    Returns (h_all, h_last). Parallel prefix (associative scan).
    """
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    a_cum, b_cum = jax.lax.associative_scan(comb, (a, bu), axis=1)
    h_all = b_cum + a_cum * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_mixer(p: dict, cfg: ModelConfig, x: jax.Array, *,
                cache: Optional[dict] = None, qmode: str = "none"):
    """x: (B,S,D) → (y, new_cache). cache = {'h': (B,di,N) f32,
    'conv': (B,cw-1,di)} for decode/prefill continuation."""
    b, s, d = x.shape
    di, n, r = cfg.d_inner, cfg.ssm_state_dim, cfg.dt_rank

    xz = linear(x, p["in_proj"], qmode=qmode)
    x_in, z = xz[..., :di], xz[..., di:]
    x_in = logical(x_in, "batch", "seq", "ssm_inner")

    prev_conv = cache["conv"] if cache is not None else None
    x_c, new_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], prev_conv)
    x_c = jax.nn.silu(x_c.astype(jnp.float32)).astype(x.dtype)

    dbc = linear(x_c, p["x_proj"], qmode=qmode)
    dt, bm, cm = dbc[..., :r], dbc[..., r:r + n], dbc[..., r + n:]
    dt = jax.nn.softplus(
        linear(dt, p["dt_proj"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    a_mat = -jnp.exp(p["A_log"])                                   # (di, N)
    # decay and driving terms, f32: (B,S,di,N)
    dec = jnp.exp(dt[..., None] * a_mat[None, None])
    bu = (dt * x_c.astype(jnp.float32))[..., None] * bm.astype(jnp.float32)[:, :, None, :]

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((b, di, n), jnp.float32))

    nseg = cfg.ssm_seq_chunks if s > cfg.ssm_seq_chunks and s % cfg.ssm_seq_chunks == 0 else 1
    seg = s // nseg
    ys = []
    h = h0
    for i in range(nseg):                     # python-unrolled: FLOPs counted
        sl = slice(i * seg, (i + 1) * seg)
        h_all, h = _ssm_scan_segment(dec[:, sl], bu[:, sl], h)
        ys.append(jnp.einsum("bsdn,bsn->bsd", h_all, cm.astype(jnp.float32)[:, sl]))
    y = jnp.concatenate(ys, axis=1)
    y = y + p["D"].astype(jnp.float32) * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = logical(y, "batch", "seq", "ssm_inner")

    out = linear(y, p["out_proj"], qmode=qmode)
    new_cache = {"h": h, "conv": new_conv} if cache is not None else None
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, cfg.d_inner), dtype),
    }
