"""Decoder-LM assembly: embeddings → N blocks (mixer + FFN) → head.

One code path drives all ten assigned architectures via ``ModelConfig``:
mixer per layer ∈ {attn, mamba, rwkv}, FFN per layer ∈ {dense, moe,
rwkv_cmix}. Layers are **python-unrolled** (a deliberate dry-run requirement:
`compiled.cost_analysis()` counts `while` bodies once, so production configs
avoid `lax.scan` over layers; see DESIGN.md).

Serving-time CAMP integration: :func:`quantize_params` converts every GEMM
weight to a :class:`QuantizedTensor`; the same forward then routes through
the quantized pipeline.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.modules import gated_mlp, linear, rms_norm, softmax_xent
from repro.parallel.sharding import logical

MOE_AUX_COEF = 0.01


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_params(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict = {
        "embedding": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "layers": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size)) * 0.02).astype(dt)
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i + 2])
        layer: dict = {"ln1": jnp.ones((cfg.d_model,), dt),
                       "ln2": jnp.ones((cfg.d_model,), dt)}
        mixer = cfg.mixer_of(i)
        if mixer == "attn":
            layer["attn"] = attn_mod.init_attention(k1, cfg, dt)
        elif mixer == "mamba":
            layer["mamba"] = ssm_mod.init_mamba(k1, cfg, dt)
        elif mixer == "rwkv":
            layer["rwkv_tm"] = rwkv_mod.init_rwkv_time_mix(k1, cfg, dt)
        else:
            raise ValueError(mixer)
        ffn = cfg.ffn_of(i)
        if ffn == "dense":
            k3 = jax.random.split(k2, 3)
            d, f = cfg.d_model, cfg.d_ff
            layer["mlp"] = {
                "w_gate": (jax.random.normal(k3[0], (d, f)) * d ** -0.5).astype(dt),
                "w_up": (jax.random.normal(k3[1], (d, f)) * d ** -0.5).astype(dt),
                "w_down": (jax.random.normal(k3[2], (f, d)) * f ** -0.5).astype(dt),
            }
        elif ffn == "moe":
            layer["moe"] = moe_mod.init_moe(k2, cfg, dt)
        elif ffn == "rwkv_cmix":
            layer["rwkv_cm"] = rwkv_mod.init_rwkv_channel_mix(k2, cfg, dt)
        params["layers"].append(layer)
    return params


def _block(lp: dict, cfg: ModelConfig, i: int, h: jax.Array,
           positions: jax.Array, cache: Optional[dict], cache_pos,
           qmode: str):
    """One residual block. Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
    mixer = cfg.mixer_of(i)
    if mixer == "attn":
        y, c_new = attn_mod.attention(
            lp["attn"], cfg, hn, positions,
            cache=None if cache is None else cache.get("attn"),
            cache_pos=cache_pos, qmode=qmode)
        c_out = None if c_new is None else {"attn": c_new}
    elif mixer == "mamba":
        y, c_new = ssm_mod.mamba_mixer(
            lp["mamba"], cfg, hn,
            cache=None if cache is None else cache.get("mamba"), qmode=qmode)
        c_out = None if c_new is None else {"mamba": c_new}
    else:  # rwkv
        y, c_new = rwkv_mod.rwkv_time_mix(
            lp["rwkv_tm"], cfg, hn,
            cache=None if cache is None else cache.get("rwkv_tm"), qmode=qmode)
        c_out = None if c_new is None else {"rwkv_tm": c_new}
    h = h + y
    h = logical(h, "batch", "seq_act", "embed")

    hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
    ffn = cfg.ffn_of(i)
    if ffn == "dense":
        y = gated_mlp(hn, lp["mlp"], qmode=qmode)
    elif ffn == "moe":
        y, aux = moe_mod.moe_ffn(lp["moe"], cfg, hn, qmode=qmode)
    else:  # rwkv channel mix
        y, c_cm = rwkv_mod.rwkv_channel_mix(
            lp["rwkv_cm"], cfg, hn,
            cache=None if cache is None else cache.get("rwkv_cm"), qmode=qmode)
        if c_cm is not None:
            c_out = {**(c_out or {}), "rwkv_cm": c_cm}
    h = h + y
    h = logical(h, "batch", "seq_act", "embed")
    return h, c_out, aux


def forward(params: dict, cfg: ModelConfig, inputs: jax.Array,
            positions: Optional[jax.Array] = None, *,
            caches: Optional[list] = None, cache_pos=None,
            qmode: Optional[str] = None, last_logits_only: bool = False,
            return_hidden: bool = False):
    """inputs: int tokens (B,S) or float embeddings (B,S,D) when
    ``cfg.embedding_inputs``. Returns (logits, new_caches, aux).

    ``last_logits_only``: compute the head for the final position only
    (prefill never needs the other 32k×V logits).
    ``return_hidden``: return the final hidden states instead of logits
    (the training loss streams the head via ``chunked_xent``).
    """
    qmode = cfg.qmode if qmode is None else qmode
    b, s = inputs.shape[:2]
    if positions is None:
        base = jnp.arange(s)[None] if cache_pos is None else cache_pos + jnp.arange(s)[None]
        positions = jnp.broadcast_to(base, (b, s))

    if jnp.issubdtype(inputs.dtype, jnp.integer):
        h = params["embedding"][inputs].astype(_dtype(cfg))
    else:
        assert cfg.embedding_inputs, "float inputs need embedding_inputs cfg"
        h = inputs.astype(_dtype(cfg))
    h = logical(h, "batch", "seq_act", "embed")

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None
    for i, lp in enumerate(params["layers"]):
        cache_i = caches[i] if caches is not None else None
        if cfg.remat and caches is None:
            blk = jax.checkpoint(
                lambda lp_, h_, i_=i: _block(lp_, cfg, i_, h_, positions,
                                             None, cache_pos, qmode))
            h, _, aux = blk(lp, h)
        else:
            h, c_out, aux = _block(lp, cfg, i, h, positions, cache_i,
                                   cache_pos, qmode)
            if new_caches is not None:
                new_caches.append(c_out)
        aux_total = aux_total + aux

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, new_caches, aux_total
    if last_logits_only:
        h = h[:, -1:]
    head = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    logits = linear(h, head, qmode="none" if cfg.tie_embeddings else qmode)
    # batch_out drops the model axis from the batch so 'vocab' can carry it:
    # vocab-sharded logits keep the (B,S,V) xent buffers and the lm_head/
    # embedding f32 gradients sharded (biggest single-param grad in training).
    logits = logical(logits, "batch_out", None, "vocab")
    return logits, new_caches, aux_total


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """batch = {'inputs': (B,S) int or (B,S,D) float, 'labels': (B,S) int}.

    Streams the vocabulary head (chunked_xent) — full (B,S,V) logits are
    never materialized.
    """
    from repro.models.modules import chunked_xent
    h, _, aux = forward(params, cfg, batch["inputs"], return_hidden=True)
    head = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    loss = chunked_xent(h, head, batch["labels"])
    if cfg.moe_experts:
        loss = loss + MOE_AUX_COEF * aux
    return loss


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                kv_dtype: Optional[str] = None) -> list:
    """Per-layer decode caches; ``kv_dtype='int8'`` quantizes attention KV
    (per-page dynamic scales — see :mod:`repro.serving.kv_cache`)."""
    dt = _dtype(cfg)
    caches = []
    for i in range(cfg.n_layers):
        mixer = cfg.mixer_of(i)
        c: dict = {}
        if mixer == "attn":
            c["attn"] = attn_mod.init_cache(cfg, batch, max_len, dt,
                                            kv_dtype=kv_dtype)
        elif mixer == "mamba":
            c["mamba"] = ssm_mod.init_mamba_cache(cfg, batch, dt)
        else:
            h = cfg.d_model // cfg.rwkv_head_dim
            c["rwkv_tm"] = {
                "s": jnp.zeros((batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                               jnp.float32),
                "x_prev": jnp.zeros((batch, cfg.d_model), dt),
            }
        if cfg.ffn_of(i) == "rwkv_cmix":
            c["rwkv_cm"] = {"x_prev": jnp.zeros((batch, cfg.d_model), dt)}
        caches.append(c)
    return caches


# ---------------------------------------------------------------------------
# PTQ: CAMP-quantize every GEMM weight in a params tree
# ---------------------------------------------------------------------------
_QUANT_KEYS = {"wq", "wk", "wv", "wo", "wr", "wg", "w_gate", "w_up", "w_down",
               "in_proj", "out_proj", "x_proj", "lm_head"}
_MIN_K = 64   # skip tiny projections (LoRA/dt) — not worth integer path


def quantize_params(params: dict, cfg: ModelConfig, qmode: str) -> dict:
    """Post-training quantization pass: weights → QuantizedTensor (CAMP)."""
    from repro.core.camp import prepare_weight, weight_bits
    from repro.models.moe import quantize_expert_weight
    if qmode == "none":
        return params
    bits = weight_bits(qmode)

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, path + (i,)) for i, v in enumerate(tree)]
        key = path[-1] if path else ""
        if (key in _QUANT_KEYS and hasattr(tree, "ndim")):
            if tree.ndim == 2 and tree.shape[0] >= _MIN_K and tree.shape[0] % 2 == 0:
                if "experts" in path:
                    return quantize_expert_weight(tree[None], bits)  # defensive
                return prepare_weight(tree, qmode)
            if tree.ndim == 3 and "experts" in path and tree.shape[1] % 2 == 0:
                return quantize_expert_weight(tree, bits)
        return tree

    return walk(params)
