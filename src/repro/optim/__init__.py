from repro.optim.adamw import adamw, int8_moment_dequant, int8_moment_quant
from repro.optim.schedule import cosine_schedule
