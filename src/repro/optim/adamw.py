"""AdamW with optional int8-quantized moments.

The quantized-moment option is the CAMP storage idea applied to optimizer
state: each moment tensor is stored as an int8 payload **in the parameter's
own shape** plus per-row (last-axis) f32 absmax scales, so moment shardings
mirror parameter shardings exactly (FSDP-friendly). The second moment is
quantized through a sqrt transform (``q = sqrt(v)/scale``) to compress its
dynamic range — the standard 8-bit-Adam trick.

For the ≥70B assigned archs this is what fits optimizer state in HBM at 256
chips (see EXPERIMENTS.md §Dry-run): m,v drop from 8 B/param (f32) to
~2 B/param.

Functional API (optax-like):

    opt = adamw(lr=..., quantize_moments=True)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = jax.tree.map(lambda p, u: p + u, params, updates)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp


def int8_moment_quant(x: jax.Array, *, sqrt_transform: bool = False) -> dict:
    """f32 tensor → {'q': int8 same-shape, 'scale': f32 (..., 1)}."""
    x32 = x.astype(jnp.float32)
    if sqrt_transform:
        x32 = jnp.sqrt(jnp.maximum(x32, 0.0))
    if x32.ndim == 0:
        x32 = x32[None]
        absmax = jnp.abs(x32)
    else:
        absmax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def int8_moment_dequant(m: dict, *, sqrt_transform: bool = False,
                        scalar: bool = False) -> jax.Array:
    x = m["q"].astype(jnp.float32) * m["scale"]
    if sqrt_transform:
        x = jnp.square(x)
    if scalar:
        x = x[0]
    return x


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def adamw(lr: Union[float, Callable[[jax.Array], jax.Array]] = 1e-3,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, quantize_moments: bool = False,
          grad_clip_norm: Optional[float] = 1.0) -> Optimizer:
    def _lr(step):
        return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

    def _qm(x, sqrt_t=False):
        if quantize_moments:
            return int8_moment_quant(x, sqrt_transform=sqrt_t)
        return x.astype(jnp.float32)

    def _dqm(m, like, sqrt_t=False):
        if quantize_moments:
            return int8_moment_dequant(m, sqrt_transform=sqrt_t,
                                       scalar=(like.ndim == 0))
        return m

    def init(params):
        return {
            "m": jax.tree.map(lambda p: _qm(jnp.zeros_like(p, jnp.float32)), params),
            "v": jax.tree.map(lambda p: _qm(jnp.zeros_like(p, jnp.float32), True), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        if grad_clip_norm is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            clip = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        leaf = lambda x: isinstance(x, dict) and "q" in x if quantize_moments else None
        m_new = jax.tree.map(
            lambda mq, g, p: b1 * _dqm(mq, p) + (1 - b1) * g,
            state["m"], grads, params, is_leaf=leaf)
        v_new = jax.tree.map(
            lambda vq, g, p: b2 * _dqm(vq, p, True) + (1 - b2) * jnp.square(g),
            state["v"], grads, params, is_leaf=leaf)
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)
        step_lr = _lr(count)

        def upd(p, m, v):
            u = -(step_lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps))
            if weight_decay:
                u = u - step_lr * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, params, m_new, v_new)
        new_state = {
            "m": jax.tree.map(lambda x: _qm(x), m_new),
            "v": jax.tree.map(lambda x: _qm(x, True), v_new),
            "count": count,
        }
        return updates, new_state

    return Optimizer(init=init, update=update)
