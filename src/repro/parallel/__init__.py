"""Distribution layer: logical-axis sharding rules, mesh context, collectives."""
from repro.parallel.sharding import (
    MeshCtx,
    active_ctx,
    logical,
    make_rules,
    mesh_context,
    params_pspecs,
    spec_for,
)
