"""Manual collective primitives (shard_map): latency-hiding ring collective
matmul and int8-compressed gradient all-reduce.

GSPMD places collectives automatically but schedules them *around* compute;
these shard_map versions express the overlapped schedule explicitly:

* ``ring_collective_matmul`` — computes ``x @ W`` with W column-sharded and x
  row-sharded on the same axis, by rotating x shards around the ring
  (collective-permute) and accumulating one partial GEMM per hop. The wire
  bytes equal one all-gather of x, but every hop's transfer overlaps the
  previous hop's GEMM on real hardware (TPU ICI is DMA-driven) — the
  classic Megatron/TPU "collective matmul" that XLA's
  --xla_tpu_enable_async_collective_permute reproduces.
* ``int8_allreduce_mean`` — the CAMP storage idea applied to the gradient
  all-reduce: quantize → psum int32 → dequantize. 4× wire reduction vs f32
  psum with absmax-scale correctness (scales combined via max).
* ``quantized_psum`` — the same wire compression for the *serving* hot path:
  an inside-``shard_map`` helper that all-reduces the row-parallel partial
  projection outputs (attention ``wo``, MLP ``w_down``) with an int8 payload.
  Tensor-parallel decode's only inter-device traffic is these two reductions
  per layer, so compressing them (4× wire at tp=2, shrinking toward
  break-even at tp=8 — see the function docstring) is the collective-side
  half of the CAMP bandwidth argument.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def ring_collective_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                           axis: str = "model"):
    """x: (M, K) sharded (axis, None); w: (K, N) sharded (None, axis).

    Device j owns x_j (M/p, K) and w_j (K, N/p) and must produce the full
    column block y_j = concat_i(x_i) @ w_j. Instead of all-gathering x up
    front, the ring rotates x shards: at every hop each device multiplies the
    shard it currently holds into the matching row block of y_j while the
    next shard is in flight — transfer overlapped with GEMM. Total wire
    bytes equal one all-gather of x; exposed latency ≈ one hop.
    """
    p = mesh.shape[axis]

    def body(x_blk, w_blk):
        idx = jax.lax.axis_index(axis)
        m_blk = x_blk.shape[0]
        y = jnp.zeros((m_blk * p, w_blk.shape[1]), w_blk.dtype)
        cur = x_blk
        for step in range(p):
            src_idx = (idx + step) % p       # whose rows we currently hold
            y = jax.lax.dynamic_update_slice(
                y, (cur @ w_blk).astype(y.dtype), (src_idx * m_blk, 0))
            if step != p - 1:
                perm = [(i, (i - 1) % p) for i in range(p)]
                cur = jax.lax.ppermute(cur, axis, perm)
        return y

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis, None), P(None, axis)),
                   out_specs=P(None, axis))
    return fn(x, w)


def quantized_psum(y: jax.Array, axis: str) -> jax.Array:
    """All-reduce-sum with int8 payload on the wire (call INSIDE shard_map).

    ``y`` is one device's partial sum (e.g. a row-parallel GEMM's local
    output). Every shard quantizes against the GLOBAL absmax (one scalar
    psum-max), the **int8** payloads are all-gathered — each device wires
    (p-1) · N int8 bytes (every peer's full partial), vs 2·(p-1)/p · 4N
    for a ring f32 psum — and each device sums the counts locally in int32
    (exact; no per-hop requantization), then dequantizes. That is a 4× wire
    reduction at p=2, ~2× at p=4, and ~break-even by p=8: right for the
    small TP degrees decode serves at. (A requantizing int8 ring
    reduce-scatter would keep the 4× at any p at the cost of per-hop
    rounding — noted as a follow-up, not done here.) The result is correct
    up to the one shared quantization step, a ~1/255-of-absmax perturbation
    far below the int8 activation-quantization noise already present on the
    serving path; the local p-way add is negligible next to the GEMM that
    produced the partial.
    """
    y32 = y.astype(jnp.float32)
    absmax = jax.lax.pmax(jnp.max(jnp.abs(y32)), axis)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(y32 / scale), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q, axis)          # int8 on the wire
    total = jnp.sum(gathered.astype(jnp.int32), axis=0)
    return total.astype(jnp.float32) * scale


def int8_allreduce_mean(g: jax.Array, mesh: Mesh, axis: str = "data"):
    """Mean-all-reduce of a gradient with int8 payload on the wire.

    Each shard quantizes with the GLOBAL absmax (one scalar psum-max), then
    psums int32 counts — exact mean up to the shared quantization step.
    """
    p = mesh.shape[axis]

    def body(blk):
        absmax = jax.lax.pmax(jnp.max(jnp.abs(blk)).astype(jnp.float32), axis)
        scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
        q = jnp.clip(jnp.round(blk.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis)
        return (total.astype(jnp.float32) * scale / p).astype(blk.dtype)

    fn = shard_map(body, mesh=mesh, in_specs=P(*(None,) * g.ndim),
                   out_specs=P(*(None,) * g.ndim))
    return fn(g)
