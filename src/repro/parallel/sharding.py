"""Divisibility-aware logical-axis sharding (MaxText-style, smaller).

Model code never names mesh axes. It annotates tensors with *logical* dim
names (``logical(x, 'batch', 'seq_act', 'embed')``); a rule table maps logical
names to mesh-axis candidates. A rule only binds when the dimension size is
divisible by the mesh axis size and the axis is not already used by another
dim of the same tensor — this is what makes qwen2-0.5b's 14 heads (indivisible
by model=16) degrade gracefully to replicated attention while its d_ff=4864
still shards.

Outside a :func:`mesh_context`, every helper is a no-op, so the same model
code runs single-device tests unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar[Optional["MeshCtx"]] = contextvars.ContextVar(
    "repro_mesh_ctx", default=None)


class MeshCtx:
    def __init__(self, mesh: Mesh, rules: Mapping[str, Sequence[str]],
                 mode: str = "train",
                 opts: Optional[Mapping[str, Any]] = None):
        self.mesh = mesh
        self.rules = dict(rules)
        self.mode = mode
        self.opts = dict(opts or {})   # e.g. {'tp_int8_reduce': True}

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name]


def active_ctx() -> Optional[MeshCtx]:
    return _CTX.get()


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Mapping[str, Sequence[str]],
                 mode: str = "train",
                 opts: Optional[Mapping[str, Any]] = None):
    tok = _CTX.set(MeshCtx(mesh, rules, mode, opts))
    try:
        with mesh:           # classic pjit-style mesh context
            yield _CTX.get()
    finally:
        _CTX.reset(tok)


def serve_tp() -> tuple:
    """(mesh, model_axis_size) of an active *serving* mesh context, else
    (None, 1).

    The serving engine enters ``mesh_context(mesh, rules, mode='serve')``
    around every forward; model code (attention's paged branches, the
    row-parallel projections) uses this to decide whether the explicit
    shard_map tensor-parallel call paths apply. Callers must still check
    divisibility per tensor — through :func:`effective_model_shards` for
    the head-sharded paths — so an indivisible head count degrades to the
    replicated single-device path (the qwen2-0.5b 14-head precedent).
    """
    ctx = active_ctx()
    if ctx is None or ctx.mode != "serve":
        return None, 1
    size = dict(ctx.mesh.shape).get("model", 1)
    if size <= 1:
        return None, 1
    return ctx.mesh, size


def effective_model_shards(mesh, n_kv_heads: int) -> int:
    """Sharding degree the head-sharded serving path actually gets.

    The ONE copy of the kv-head divisibility rule: the mesh's model-axis
    size when it divides ``n_kv_heads``, else 1 (replicated fallback). The
    engine, the page pool, the attention routing and the serve entrypoint
    all consult this, so page storage layout and kernel dispatch can never
    disagree about whether heads are sharded.
    """
    if mesh is None:
        return 1
    tp = dict(mesh.shape).get("model", 1)
    return tp if tp > 1 and n_kv_heads % tp == 0 else 1


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------
def make_rules(mode: str = "train", multi_pod: bool = False,
               family: str = "dense") -> dict:
    """Logical-name → mesh-axis-candidate tuples (greedy prefix binding).

    Layout decisions (measured on the 512-device dry-run, see EXPERIMENTS.md
    §Perf iteration log):

    * **train** = flat FSDP/ZeRO-3: the batch carries data×model (1 sample
      per chip at gb=256), weights+optimizer 2D-sharded (fsdp=data ×
      model on the wide dim) and gathered per layer. The Megatron-TP+SP
      alternative triggers GSPMD "involuntary full rematerialization" in the
      backward pass (245 GiB temp vs 50 GiB) — documented, kept as a manual
      shard_map path, not the default.
    * **train for ssm/hybrid**: recurrences must stay shard-local in time, so
      batch carries only data; heads (WKV) / d_inner (Mamba) carry model.
    * **prefill/decode** (dense-slab serving) = classic TP: weights resident
      model-sharded; the KV cache's *sequence* dim carries the model axis
      (kv_heads=8 rarely divides 16) — decode attention becomes seq-parallel
      with partial-softmax collectives; MoE serves expert-parallel over data
      (weights resident, token a2a).
    * **serve** (paged-pool engine) = head-sharded TP: KV *page storage* and
      the q/k/v head dims carry the model axis, so paged attention is
      entirely shard-local (no collective touches the KV hot path) and the
      row-parallel wo / w_down outputs are the only all-reduces per layer.
      ``seq_kv`` stays unsharded — pages are never split along tokens — and
      indivisible head counts fall back to replicated attention via the
      divisibility check, mirroring the qwen2-0.5b precedent.
    * multi-pod: the pod axis joins the batch for serving; for training it
      carries the activation-stash sequence dim (cheap 2-way).
    """
    data = ("pod", "data") if multi_pod else ("data",)
    weights = {
        "fsdp": ("data",) if mode == "train" else (),
        "heads_flat": ("model",),
        "d_ff": ("model",),
        "vocab": ("model",),
        "head_dim": (), "embed": (), "ssm_state": (), "conv_dim": (),
        "moe_capacity": (),
    }
    if mode == "train":
        recurrent = family in ("ssm", "hybrid")
        return {
            **weights,
            "batch": ("data",) if recurrent else ("data", "model"),
            "batch_out": ("data",),
            "seq_act": ("pod",) if multi_pod else (),
            "seq": (),
            "heads": ("model",) if recurrent else (),
            "kv_heads": (),
            "ssm_inner": ("model",),
            "expert": ("model",),
            "expert_ff": (),
            "moe_group": ("data",),   # token groups ⊥ experts: the MoE a2a
            "seq_kv": (),
        }
    if mode in ("prefill", "decode"):
        return {
            **weights,
            "batch": data,
            "batch_out": data,
            "seq_act": (),
            "seq": (),
            "heads": ("model",),
            "kv_heads": ("model",),
            "ssm_inner": ("model",),
            "expert": data,            # expert-parallel serving
            "expert_ff": ("model",),
            "moe_group": (),           # serve tokens stay batch-sharded
            "seq_kv": ("model",),
        }
    if mode == "serve":
        return {
            **weights,
            "batch": data,
            "batch_out": data,
            "seq_act": (),
            "seq": (),
            "heads": ("model",),
            "kv_heads": ("model",),    # page storage shards by kv head
            "kv_pages": (),            # the page (slot) dim never splits
            "ssm_inner": ("model",),
            "expert": data,
            "expert_ff": ("model",),
            "moe_group": (),
            "seq_kv": (),              # pages are head-sharded, not seq-split
        }
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Spec resolution
# ---------------------------------------------------------------------------
def spec_for(shape: Sequence[int], names: Sequence[Optional[str]],
             rules: Mapping[str, Sequence[str]], mesh: Mesh) -> P:
    """Resolve a PartitionSpec for ``shape`` given logical dim ``names``.

    Divisibility- and reuse-checked: a mesh axis binds to at most one dim, and
    only when it divides the dim size (joint axes must divide as a product).
    """
    assert len(shape) == len(names), (shape, names)
    used: set = set()
    out = []
    for dim, name in zip(shape, names):
        if not name:
            out.append(None)
            continue
        cands = rules.get(name, ())
        axes = [a for a in cands if a in mesh.shape and a not in used]
        # Greedy prefix: take the longest prefix of candidate axes whose
        # product divides the dim.
        bound = []
        prod = 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                bound.append(a)
                prod *= mesh.shape[a]
        if bound:
            used.update(bound)
            out.append(tuple(bound) if len(bound) > 1 else bound[0])
        else:
            out.append(None)
    return P(*out)


def logical(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical dim names (no-op w/o context)."""
    ctx = active_ctx()
    if ctx is None:
        return x
    spec = spec_for(x.shape, names, ctx.rules, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter logical axes by path pattern
# ---------------------------------------------------------------------------
# Matched in order against '/'-joined param paths. First hit wins.
_PARAM_PATTERNS: list[tuple[str, tuple]] = [
    (r"embedding$",            ("vocab", "fsdp")),
    (r"lm_head$",              ("fsdp", "vocab")),
    (r"(wq|wk|wv|wr|wg)$",     ("fsdp", "heads_flat")),
    (r"(wq|wk|wv)_bias$",      ("heads_flat",)),
    (r"wo$",                   ("heads_flat", "fsdp")),
    (r"(w_gate|w_up)$",        ("fsdp", "d_ff")),
    (r"w_down$",               ("d_ff", "fsdp")),
    (r"router$",               ("fsdp", None)),
    (r"experts/(w_gate|w_up)$", ("expert", "fsdp", "expert_ff")),
    (r"experts/w_down$",       ("expert", "expert_ff", "fsdp")),
    (r"(in_proj|x_proj|rkvg|time_maa_w[12]|w_lora_[ab]|dt_proj)$", ("fsdp", None)),
    (r"out_proj$",             (None, "fsdp")),
    (r"conv_w$",               (None, "ssm_inner")),
    (r"A_log$",                ("ssm_inner", None)),
    (r"(scale|bias|norm|A|D|dt_bias|time_.*|w0|u|ln_[xw].*|g_norm.*)$", None),
]


def _axes_for_path(path: str, ndim: int):
    for pat, axes in _PARAM_PATTERNS:
        if re.search(pat, path):
            if axes is None:
                return (None,) * ndim
            if len(axes) == ndim:
                return axes
            if len(axes) < ndim:  # leading batch-ish dims unsharded
                return (None,) * (ndim - len(axes)) + tuple(axes)
            return axes[:ndim]
    return (None,) * ndim


# 'heads_flat' (= n_heads*head_dim or n_kv*head_dim columns) shards over model
# when divisible — independent of whether per-head activations shard.
_EXTRA_RULES = {"heads_flat": ("model",)}


def params_pspecs(params_tree: Any, rules: Mapping[str, Sequence[str]],
                  mesh: Mesh) -> Any:
    """PartitionSpec pytree for a params(-shape) pytree, by path patterns.

    Handles QuantizedTensor leaves: the int payload and its (1, N) scale get
    column-consistent specs.
    """
    from repro.core.quant import QuantizedTensor

    full_rules = {**rules, **_EXTRA_RULES}

    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if isinstance(leaf, QuantizedTensor):
            axes = _axes_for_path(pstr, leaf.q.ndim)
            qspec = spec_for(leaf.q.shape, axes, full_rules, mesh)
            sspec = P(*((None,) * (leaf.scale.ndim - 1) + (qspec[-1] if len(qspec) else None,)))
            return QuantizedTensor(q=qspec, scale=sspec, bits=leaf.bits, shape=leaf.shape)
        shape = leaf.shape
        axes = _axes_for_path(pstr, len(shape))
        return spec_for(shape, axes, full_rules, mesh)

    return jax.tree_util.tree_map_with_path(
        one, params_tree,
        is_leaf=lambda x: isinstance(x, QuantizedTensor))
