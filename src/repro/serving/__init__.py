from repro.serving.engine import (
    build_decode_step,
    build_prefill_step,
    init_serve_caches,
)
