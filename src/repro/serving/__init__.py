"""Serving stack: one paged, quantized KV representation end to end.

The :class:`~repro.serving.kv_cache.PagePool` is the only KV store the
continuous-batching engine touches: chunked prefill quantizes straight into
refcounted pages (:class:`~repro.serving.kv_cache.PagedPrefillCache`, no
dense staging slab), ragged decode appends to them
(:class:`~repro.serving.kv_cache.PagedDecodeCache`), prompts sharing a
prefix share physical pages through a trie, and all writes cross a
copy-on-write barrier.

Engine symbols are re-exported lazily (PEP 562): ``repro.models.attention``
imports :mod:`repro.serving.kv_cache` at module scope, and an eager
``engine`` import here would close the cycle back through
``repro.models.transformer`` before it finishes initializing.
"""
from repro.serving.kv_cache import (  # noqa: F401
    DenseKVCache,
    PagedDecodeCache,
    PagedPrefillCache,
    PagePool,
)

_ENGINE_EXPORTS = (
    "ContinuousBatchingEngine",
    "Request",
    "build_decode_step",
    "build_prefill_step",
    "generate",
    "init_serve_caches",
    "warm_gemm_autotune",
)


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
