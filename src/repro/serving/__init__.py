"""Serving stack: one paged, quantized KV representation end to end.

The :class:`~repro.serving.kv_cache.PagePool` is the only KV store the
continuous-batching engine touches: chunked prefill quantizes straight into
refcounted pages (:class:`~repro.serving.kv_cache.PagedPrefillCache`, no
dense staging slab), ragged decode appends to them
(:class:`~repro.serving.kv_cache.PagedDecodeCache`), prompts sharing a
prefix share physical pages through a trie, and all writes cross a
copy-on-write barrier. Released prefix pages park in a bounded LRU (trie
entry intact) so re-submitted prompts re-share them; eviction is LRU-first
under pool pressure. Pages are **write-once at token granularity** (one
int8 row + one scale per (page, head, token)), which makes cache state
independent of how tokens were grouped into writes — the property
speculative decoding (:mod:`repro.serving.spec_decode`) leans on: draft
tokens are written, verified by one multi-token forward, and rejected
suffixes rolled back (``PagePool.truncate``) without perturbing the kept
prefix.

Serving parallelism
-------------------
With a device mesh (``ContinuousBatchingEngine(mesh=...)``, rules from
``make_rules('serve')``), the stack is tensor-parallel over the mesh's
``model`` axis. What is **sharded**:

* KV page *storage* — each device holds ``n_kv_heads / model_shards`` heads
  of every page, with per-token scales alongside; ingest/append/write_chunk
  quantize shard-locally and the shard_map attention kernels
  (``paged_attention_tp`` / ``paged_prefill_attention_tp``) read pages
  without any cross-device traffic.
* GEMM operands — q/kv/gate/up weights column-parallel, wo/w_down
  row-parallel (the serve-mode logical rule table); the row-parallel
  partial outputs are the layer's only all-reduces, optionally
  int8-compressed on the wire (``tp_int8_reduce``).

What stays **replicated**: block tables, refcounts, the prefix trie, the
retained-page LRU, queues and every other scheduler decision — plain host
code, identical with and without a mesh, which is what keeps sharded and
single-device page accounting bit-for-bit equal. Head counts the model
axis does not divide degrade to replicated attention (engine ``tp == 1``)
with unchanged results.

Engine symbols are re-exported lazily (PEP 562): ``repro.models.attention``
imports :mod:`repro.serving.kv_cache` at module scope, and an eager
``engine`` import here would close the cycle back through
``repro.models.transformer`` before it finishes initializing.
"""
from repro.serving.kv_cache import (  # noqa: F401
    DenseKVCache,
    PagedDecodeCache,
    PagedPrefillCache,
    PagePool,
)
from repro.serving.spec_decode import (  # noqa: F401
    DraftModelDrafter,
    NGramDrafter,
    SpecConfig,
    SpecStats,
    accept_speculative,
)

_ENGINE_EXPORTS = (
    "ContinuousBatchingEngine",
    "Request",
    "build_decode_step",
    "build_prefill_step",
    "generate",
    "init_serve_caches",
    "warm_gemm_autotune",
)


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
