"""Serving steps: batched prefill + single-token decode with KV cache.

This is where the CAMP technique earns its keep at scale: decode is
memory-roofline-bound, so int8/int4 weights (``cfg.qmode``) and optionally
int8 KV cache cut the dominant roofline term 2–4×. llama4-maverick-400B
*only* fits the single-pod decode cell quantized (see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_caches


def init_serve_caches(cfg: ModelConfig, batch: int, max_len: int,
                      kv_dtype: Optional[str] = None):
    """KV/state caches; ``kv_dtype='int8'`` stores attention KV quantized.

    int8 KV uses a fixed per-cache scale folded at write/read (symmetric,
    scale baked into the dtype conversion here since rope output is O(1);
    a per-block scale variant is a straightforward extension).
    """
    caches = init_caches(cfg, batch, max_len)
    if kv_dtype == "int8":
        def conv(c):
            if isinstance(c, dict) and "k" in c and "v" in c:
                return {"k": jnp.zeros(c["k"].shape, jnp.int8),
                        "v": jnp.zeros(c["v"].shape, jnp.int8)}
            return c
        caches = [{k: conv(v) for k, v in layer.items()} for layer in caches]
    return caches


def build_prefill_step(cfg: ModelConfig, *, max_len: Optional[int] = None):
    """(params, inputs, caches) → (last_token_logits, caches)."""

    def prefill_step(params, inputs, caches):
        # last_logits_only: a 32k prefill needs the head at ONE position,
        # not a (B, 32768, V) logits tensor.
        logits, caches, _ = forward(params, cfg, inputs, caches=caches,
                                    last_logits_only=True)
        return logits[:, -1], caches

    return prefill_step


def build_decode_step(cfg: ModelConfig, *, sample: str = "greedy",
                      temperature: float = 1.0):
    """(params, caches, token, pos, key) → (next_token, caches).

    ``token``: (B, 1) int32; ``pos``: scalar int32 current position.
    """

    def decode_step(params, caches, token, pos, key=None):
        logits, caches, _ = forward(params, cfg, token, caches=caches,
                                    cache_pos=pos)
        last = logits[:, -1].astype(jnp.float32)
        if sample == "greedy":
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        return nxt[:, None].astype(jnp.int32), caches

    return decode_step


def generate(params, cfg: ModelConfig, prompt: jax.Array, *, steps: int,
             key=None, sample: str = "greedy", temperature: float = 1.0,
             max_len: Optional[int] = None):
    """Simple batched generation loop (prefill + python decode loop)."""
    b, s = prompt.shape[:2]
    max_len = max_len or (s + steps)
    caches = init_serve_caches(cfg, b, max_len)
    prefill = build_prefill_step(cfg)
    decode = build_decode_step(cfg, sample=sample, temperature=temperature)
    last, caches = prefill(params, prompt, caches)
    tok = jnp.argmax(last.astype(jnp.float32), axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(steps - 1):
        k = None if key is None else jax.random.fold_in(key, i)
        tok, caches = decode(params, caches, tok, jnp.int32(s + i), k)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
