"""Serving engine: continuous batching over a paged, quantized KV cache.

This is where the CAMP technique earns its keep at scale: decode is
memory-roofline-bound, so int8/int4 weights (``cfg.qmode``) cut the weight
stream and the paged int8 KV cache (:mod:`repro.serving.kv_cache`) cuts the
cache stream — decode reads only the pages a sequence occupies, at one byte
per element, dequantized in-register by the paged-attention kernel.

The paged pool is the **only** KV representation end to end: prefill is
**chunked** and writes quantized pages directly through
:class:`~repro.serving.kv_cache.PagedPrefillCache` (no dense per-request KV
staging slab exists anywhere in the engine), decode appends to the same
pages, and prompts sharing a prefix share physical pages copy-on-write.

Two serving modes:

* :class:`ContinuousBatchingEngine` — sequences are admitted and finished
  **mid-flight** over a shared page pool: ``submit()`` queues a request;
  every ``step()`` admits what fits (reserving only the pages a prefix
  lookup could not share), advances the head prefill by one autotuned
  chunk, and runs one ragged decode over all active sequences (per-sequence
  positions and block tables; no padding to a common length) — so a long
  prompt no longer stalls decode for the whole batch, and a long request no
  longer holds the batch hostage. Finished sequences decref their pages;
  slots return to the free list when the last sharer is done.
  ``generate()`` is a thin batch wrapper on top.
* the dense-slab path (``build_prefill_step`` / ``build_decode_step``) —
  the degenerate single-block-table case, kept for hybrid/recurrent mixers
  (SSM/RWKV carry non-KV state) and for the multi-pod dry-run cells.

Both engine modes are **mesh-native**: pass ``mesh=`` and every forward
runs under the serve-mode sharding rules — KV pages head-sharded over the
model axis, shard_map attention kernels, row-parallel output projections
with (optionally int8-compressed) all-reduces — while the scheduler itself
remains ordinary replicated host code.

The continuous engine also speaks **speculative decoding** (``spec=``):
the decode lane swaps single-token steps for draft–verify panels — a
drafter (:mod:`repro.serving.spec_decode`) proposes γ tokens, one
γ+1-token forward over the paged cache scores them, exact
acceptance–rejection keeps the agreed prefix and the pool's token-granular
``truncate`` rolls the rest back. Greedy speculative streams are
bit-identical to non-speculative ones; temperature streams preserve the
target distribution.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.models.config import ModelConfig
from repro.models.moe import expert_capacity, routing_group_size
from repro.models.transformer import forward, init_caches
from repro.parallel.sharding import (effective_model_shards, make_rules,
                                     mesh_context)
from repro.serving import kv_cache as kvc
from repro.serving import spec_decode as sd


def init_serve_caches(cfg: ModelConfig, batch: int, max_len: int,
                      kv_dtype: Optional[str] = None):
    """Dense KV/state caches; ``kv_dtype='int8'`` stores attention KV
    quantized with **per-page dynamic scales** (amax/127 of each page —
    scale handling lives in :mod:`repro.serving.kv_cache`, shared with the
    paged pool)."""
    return init_caches(cfg, batch, max_len, kv_dtype=kv_dtype)


_QMODE_KIND = {"w8a8": "i8", "w4a8": "w4", "w4a4": "a4w4"}


def warm_gemm_autotune(cfg: ModelConfig, *, batch_sizes=(1, 8, 32),
                       prefill_len: int = 0, measure=None, tp: int = 1,
                       spec_gammas=()):
    """Pre-tune CAMP GEMM blocks for the transformer's serving linears.

    Decode runs one token per sequence (M = batch) and prefill runs
    M = batch × prompt_len; both hit the same (K, N) weight shapes. Tuning
    them here — measured on a live TPU, analytic elsewhere — populates the
    persistent autotune cache so the request path never tunes. Covered:
    attention q/kv/out, dense MLP up/gate/down, MoE expert up/gate/down at
    the **expert-capacity M** the per-expert fused CAMP dispatch in
    :mod:`repro.models.moe` actually runs, and the untied lm head.
    Mixer-specific extras (SSM/RWKV projections) still cold-tune on first
    sight.

    ``tp > 1`` warms the tensor-parallel shard shapes instead: column-
    parallel projections run (m, n/tp, k) per device and the row-parallel
    wo/w_down run (m, n, k/tp) — the shapes the shard_map call paths
    actually launch. The enumeration is a set and shapes already present in
    the persistent cache are skipped, so serve-mode warming (which visits
    both the sharded and the replicated-fallback shapes across engine
    restarts) never tunes the same (M, N, K) twice.

    ``spec_gammas`` adds the speculative-decoding verify panels: a γ-token
    draft is verified by one (γ+1)-row forward per sequence, and drafters
    routinely propose *fewer* than γ tokens (no n-gram match, short
    continuations, end-of-budget clipping), so every partial panel width
    M ∈ [2, γ+1] joins the enumeration for each candidate window.

    Returns [((m, n, k), (bm, bn, bk)), ...] for logging.
    """
    kind = _QMODE_KIND.get(cfg.qmode)
    if kind is None:  # 'none' / weight-only: bf16 matmul, nothing to tune
        return []
    a_in_bytes = jnp.dtype(cfg.dtype).itemsize  # must match the request path
    d, hd = cfg.d_model, cfg.hd

    def shard(k, n, *, row_parallel):
        """Local (K, N) of one device's GEMM under tp-way model sharding."""
        if tp <= 1:
            return (k, n)
        if row_parallel:
            return (k // tp, n) if k % tp == 0 else (k, n)
        return (k, n // tp) if n % tp == 0 else (k, n)

    proj = {
        shard(d, hd * cfg.n_heads, row_parallel=False),    # q proj
        shard(d, hd * cfg.n_kv_heads, row_parallel=False),  # kv proj
        shard(hd * cfg.n_heads, d, row_parallel=True),     # attn out
        shard(d, cfg.d_ff, row_parallel=False),            # mlp up/gate
        shard(cfg.d_ff, d, row_parallel=True),             # mlp down
    }
    if not cfg.tie_embeddings:
        proj.add(shard(d, cfg.vocab_size, row_parallel=False))  # lm head
    ms = sorted({b * max(prefill_len, 1) for b in batch_sizes} |
                set(batch_sizes) |
                {m for g in spec_gammas for m in range(2, g + 2)})
    shapes = {(m, n, k) for m in ms for (k, n) in proj}
    if cfg.moe_experts:
        # expert GEMMs run at M = groups × capacity, not M = tokens
        eproj = (shard(d, cfg.expert_ff, row_parallel=False),
                 shard(cfg.expert_ff, d, row_parallel=True))
        for m in ms:
            sg = routing_group_size(m)
            em = (m // sg) * expert_capacity(sg, cfg)
            shapes |= {(max(em, 1), n, k) for (k, n) in eproj}
    out = []
    for (m, n, k) in sorted(shapes):
        if autotune.has_cached(kind, m, n, k, fused=True,
                               a_in_bytes=a_in_bytes):
            continue           # a previous warmup already paid for this one
        blk = autotune.tune(kind, m, n, k, fused=True,
                            a_in_bytes=a_in_bytes, measure=measure,
                            save=False)
        out.append(((m, n, k), blk))
    autotune.flush()  # one disk write for the whole warmup
    return out


def build_prefill_step(cfg: ModelConfig, *, max_len: Optional[int] = None):
    """(params, inputs, caches) → (last_token_logits, caches)."""

    def prefill_step(params, inputs, caches):
        # last_logits_only: a 32k prefill needs the head at ONE position,
        # not a (B, 32768, V) logits tensor.
        logits, caches, _ = forward(params, cfg, inputs, caches=caches,
                                    last_logits_only=True)
        return logits[:, -1], caches

    return prefill_step


def build_decode_step(cfg: ModelConfig, *, sample: str = "greedy",
                      temperature: float = 1.0):
    """(params, caches, token, pos, key) → (next_token, caches).

    ``token``: (B, 1) int32; ``pos``: scalar int32 current position.
    """

    def decode_step(params, caches, token, pos, key=None):
        logits, caches, _ = forward(params, cfg, token, caches=caches,
                                    cache_pos=pos)
        last = logits[:, -1].astype(jnp.float32)
        if sample == "greedy":
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        return nxt[:, None].astype(jnp.int32), caches

    return decode_step


# ---------------------------------------------------------------------------
# Continuous batching over the shared page pool
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    """One in-flight generation request."""
    seq_id: int
    prompt: jax.Array                    # (S,) int32
    max_new_tokens: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0                         # prompt tokens cached so far
    done: bool = False
    spec: sd.SpecStats = dataclasses.field(default_factory=sd.SpecStats)

    def __post_init__(self):
        # host-side token tuple: prefix-trie keys + chunk slicing without
        # device round-trips per step
        self.prompt_tokens = tuple(np.asarray(self.prompt).tolist())

    @property
    def reserve_tokens(self) -> int:
        return int(self.prompt.shape[0]) + self.max_new_tokens


class ContinuousBatchingEngine:
    """Admit/finish sequences mid-flight over a shared paged KV pool.

    Scheduling is conservative: a request is admitted only when the pool can
    reserve its worst-case page count (prompt + max_new_tokens, minus the
    prefix pages a trie lookup can share), so an admitted sequence can never
    stall mid-decode waiting for pages. Each ``step()``:

    1. admits the next queued request once the prefill lane is clear —
       admission looks up the prompt in the pool's prefix trie, **shares**
       the pages of any registered prefix (refcounted, copy-on-write) and
       reserves only the remainder; admitting one prefill at a time lets a
       burst of same-prefix prompts share the pages the first one writes;
    2. advances the head prefill by one autotuned **chunk** (``pprefill|``
       autotune keys): the chunk's KV quantizes straight into the
       sequence's pages and attends over the cached prefix through the
       chunked paged-prefill kernel — no dense per-request KV slab exists,
       and a 32k prompt no longer blocks the batch for its whole prefill;
    3. runs **one ragged decode** over every active sequence: per-sequence
       positions, per-sequence block tables, one forward pass — attention
       goes through the paged int8 kernel, so a step's HBM traffic is the
       pages actually occupied, not ``batch × max_len``;
    4. retires sequences that hit their token budget and decrefs their
       pages — a slot returns to the free list when its last sharer is done.

    Per-sequence results are independent of co-scheduling: pages are owned
    exclusively or shared immutably (every write path crosses the pool's
    copy-on-write barrier), per-token scales depend only on a token's own
    content, attention is masked per sequence length, chunk boundaries
    depend only on the engine's static chunk size, and sampling keys are
    derived per (seq_id, token index) — a sequence decodes identically
    whether it runs alone or inside a changing batch.

    **Tensor parallelism.** With ``mesh=`` (a (data, model) device mesh),
    every forward runs inside a ``mode='serve'`` mesh context: the pool's
    page storage is head-sharded over the model axis, the paged kernels run
    their shard_map wrappers (KV hot path collective-free), and the
    row-parallel wo/w_down projections all-reduce their partial outputs —
    int8-compressed on the wire when ``tp_int8_reduce``. Scheduler state
    (queues, block tables, trie, refcounts) stays replicated host-side, so
    admission/retirement logic is identical with and without a mesh; a
    kv-head count indivisible by the model axis degrades to replicated
    attention and the engine behaves exactly as on a single device.

    **Speculative decoding.** With ``spec=``
    (:class:`repro.serving.spec_decode.SpecConfig`, method 'ngram' or
    'draft'), the decode lane runs **draft–verify** steps instead of
    single-token ragged decodes: per active sequence, the drafter proposes
    up to γ tokens, their KV is written into the sequence's pages (crossing
    the COW barrier page by page) and the whole γ+1-token panel is scored
    by ONE forward through the chunked paged-prefill path — then exact
    acceptance–rejection keeps the agreed prefix and
    :meth:`~repro.serving.kv_cache.PagePool.truncate` rolls the rejected
    suffix back. Write-once token-granular pages make the rollback
    bit-exact, so greedy speculative streams are identical to
    non-speculative ones and temperature streams preserve the target
    distribution for any drafter. Speculation targets small-batch,
    latency-bound serving (the verify forwards run per sequence);
    mid-prefill requests keep the normal chunked path, and hybrid
    SSM/RWKV models never reach this engine at all. Drafting always runs
    replicated (outside the mesh scope); only verification is
    tensor-parallel. ``gamma='auto'`` re-picks the window from the
    measured acceptance rate through the autotune cache's ``spec|`` keys.
    """

    SPEC_RETUNE_EVERY = 16               # spec steps between auto-γ re-picks

    def __init__(self, params, cfg: ModelConfig, *,
                 kv_dtype: Optional[str] = "int8",
                 page_size: Optional[int] = None,
                 capacity_tokens: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 pages_per_step: Optional[int] = None,
                 sample: str = "greedy", temperature: float = 1.0,
                 key: Optional[jax.Array] = None,
                 mesh=None, rules=None, tp_int8_reduce: bool = False,
                 retain_pages: Optional[int] = None,
                 spec: Optional[sd.SpecConfig] = None):
        mixers = {cfg.mixer_of(i) for i in range(cfg.n_layers)}
        if mixers != {"attn"}:
            raise ValueError(
                f"continuous batching requires attention mixers, got {mixers}"
                " (hybrid/recurrent models use the dense serving path)")
        self.params, self.cfg = params, cfg
        self.sample, self.temperature = sample, temperature
        self.key = jax.random.PRNGKey(0) if key is None else key
        self.mesh = mesh
        self.rules = rules if rules is not None else (
            make_rules("serve") if mesh is not None else None)
        self.tp_int8_reduce = tp_int8_reduce
        # sharding degree the pool/kernels actually get (replicated fallback
        # for head counts the model axis doesn't divide)
        self.tp = effective_model_shards(mesh, cfg.n_kv_heads)
        # page size / prefill chunking come from the persistent autotune
        # cache (analytic v5e model off-TPU) unless pinned by the caller
        mean_len = max(cfg.max_seq_len // 2, 128)
        ps = page_size or autotune.get_page_size(
            cfg.n_kv_heads, cfg.hd, mean_len=mean_len)
        chunk, pp = autotune.get_prefill_params(
            cfg.n_kv_heads, cfg.hd, ps, mean_len=mean_len)
        chunk = prefill_chunk or chunk
        # non-final chunks must cover whole pages so a partial page is
        # quantized exactly once (by the final chunk)
        self.chunk_tokens = max(ps, chunk - chunk % ps)
        self.pages_per_step = pages_per_step or pp
        capacity_tokens = capacity_tokens or 8 * cfg.max_seq_len
        self.pool = kvc.PagePool(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            num_pages=-(-capacity_tokens // ps), page_size=ps,
            quantized=(kv_dtype == "int8"), dtype=jnp.dtype(cfg.dtype),
            mesh=mesh if self.tp > 1 else None, retain_pages=retain_pages)
        self.waiting: collections.deque = collections.deque()
        self.prefilling: collections.deque = collections.deque()
        self.active: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._next_id = 0
        # -- speculative decoding ---------------------------------------
        self.spec_cfg = spec if spec is not None and spec.method != "off" \
            else None
        self.drafter = None
        self.spec_totals = sd.SpecStats()
        if self.spec_cfg is not None:
            self.drafter = sd.make_drafter(
                self.spec_cfg, sample=sample, temperature=temperature,
                key=jax.random.fold_in(self.key, 0x5bec))
            self._spec_auto = self.spec_cfg.gamma == "auto"
            self._spec_last_tune = 0
            self.spec_gamma = (autotune.DEFAULT_SPEC_GAMMA if self._spec_auto
                               else int(self.spec_cfg.gamma))
            if self.spec_gamma < 1:
                raise ValueError(f"spec gamma {self.spec_gamma} < 1")

    def _mesh_scope(self):
        """Serve-mode mesh context for one engine step (no-op without mesh)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return mesh_context(self.mesh, self.rules, mode="serve",
                            opts={"tp_int8_reduce": self.tp_int8_reduce})

    # -- request lifecycle ----------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> int:
        """Queue a prompt; returns its sequence id."""
        prompt = jnp.asarray(prompt, jnp.int32).reshape(-1)
        seq_id = self._next_id
        self._next_id += 1
        self.waiting.append(Request(seq_id, prompt, max_new_tokens))
        return seq_id

    def _sample_tokens(self, logits: jax.Array,
                       reqs: List[Request]) -> jax.Array:
        """logits (B, V) → (B,) int32; rows align with ``reqs``.

        Non-greedy keys are folded from (engine key, seq_id, token index),
        never from a shared stream — so sampled tokens don't depend on which
        other sequences happen to share the batch.
        """
        last = logits.astype(jnp.float32)
        if self.sample == "greedy":
            return jnp.argmax(last, axis=-1)
        keys = jnp.stack([
            jax.random.fold_in(jax.random.fold_in(self.key, r.seq_id),
                               len(r.tokens))
            for r in reqs])
        return jax.vmap(
            lambda k, l: jax.random.categorical(k, l / self.temperature)
        )(keys, last)

    def _finish(self, req: Request) -> None:
        self.pool.release(req.seq_id)
        if self.drafter is not None:
            self.drafter.release(req.seq_id)
        req.done = True
        self.finished[req.seq_id] = req

    def _admit(self) -> None:
        """Admit the next queued request once the prefill lane is clear.

        One prefill in flight at a time: by the time the next request is
        admitted, the previous prompt's full pages are registered in the
        prefix trie, so a burst of same-prefix prompts shares pages instead
        of each writing its own copy. Admission reserves only the pages the
        prefix lookup could not share.
        """
        while self.waiting and not self.prefilling:
            nxt: Request = self.waiting[0]
            if not self.pool.can_reserve(nxt.reserve_tokens,
                                         prompt=nxt.prompt_tokens):
                if not self.active:
                    raise RuntimeError(
                        f"request {nxt.seq_id} needs "
                        f"{self.pool.pages_for(nxt.reserve_tokens)} pages; "
                        f"pool has {self.pool.num_pages} total")
                break
            self.waiting.popleft()
            nxt.pos = self.pool.reserve(nxt.seq_id, nxt.reserve_tokens,
                                        prompt=nxt.prompt_tokens)
            self.prefilling.append(nxt)

    def _run_prefill_chunk(self, req: Request, chunk: int,
                           need_logits: bool):
        """One chunk of paged prefill: tokens [pos, pos+chunk) straight into
        the pool's pages (no dense staging slab). Mid-prompt chunks skip
        the vocabulary head entirely."""
        t0 = req.pos
        logits = sd.paged_chunk_forward(
            self.params, self.cfg, self.pool, req.seq_id,
            req.prompt[t0:t0 + chunk], t0,
            pages_per_step=self.pages_per_step,
            logits="last" if need_logits else "none")
        req.pos = t0 + chunk
        return logits

    def _prefill_step(self) -> None:
        """Advance the head prefill by up to ``chunk_tokens`` prompt tokens.

        Non-final chunks are page-aligned, so every page is quantized
        exactly once; the final chunk registers the prompt's full pages in
        the prefix trie and moves the request to the decode lane.
        """
        budget = self.chunk_tokens
        while budget > 0 and self.prefilling:
            req: Request = self.prefilling[0]
            s = int(req.prompt.shape[0])
            remaining = s - req.pos
            chunk = min(budget, remaining)
            if chunk < remaining:
                chunk -= chunk % self.pool.page_size
                if chunk == 0:
                    break        # leftover budget smaller than one page
            logits = self._run_prefill_chunk(req, chunk,
                                             need_logits=(req.pos + chunk == s))
            budget -= chunk
            if req.pos < s:
                continue
            self.prefilling.popleft()
            self.pool.register_prefix(req.seq_id, req.prompt_tokens)
            req.tokens.append(int(self._sample_tokens(logits[:, -1], [req])[0]))
            if len(req.tokens) >= req.max_new_tokens:
                self._finish(req)
            else:
                self.active.append(req)

    def _decode(self) -> None:
        """One ragged decode step over all active sequences."""
        reqs = list(self.active)
        seq_ids = [r.seq_id for r in reqs]
        ps = self.pool.page_size
        for r in reqs:
            # COW barrier: the page this append touches must be exclusive
            self.pool.ensure_writable(r.seq_id, self.pool.lens[r.seq_id] // ps)
        tokens = jnp.asarray([[r.tokens[-1]] for r in reqs], jnp.int32)
        tables, lengths = self.pool.batch_tables(seq_ids)
        caches = [{"attn": self.pool.layer_cache(i, tables, lengths)}
                  for i in range(self.cfg.n_layers)]
        logits, new_caches, _ = forward(self.params, self.cfg, tokens,
                                        positions=lengths[:, None],
                                        caches=caches)
        for i, layer in enumerate(new_caches):
            self.pool.writeback(i, layer["attn"])
        for r in reqs:
            self.pool.lens[r.seq_id] += 1
        nxt = np.asarray(self._sample_tokens(logits[:, -1], reqs))
        self.active = []
        for r, t in zip(reqs, nxt):
            r.tokens.append(int(t))
            if len(r.tokens) >= r.max_new_tokens:
                self._finish(r)
            else:
                self.active.append(r)

    # -- speculative decode lane -----------------------------------------
    def _spec_verify(self, req: Request, draft: List[int]) -> np.ndarray:
        """Score [last_sampled] + draft in one forward over the paged cache.

        The panel's KV is written into the sequence's pages first (each
        touched page crosses the COW barrier), then the γ+1-token query
        attends over the whole cached prefix through the chunked
        paged-prefill path — ``q_start`` is wherever decode left off,
        page-aligned or not. Returns the (γ+1, V) f32 logit rows; the
        caller rolls the rejected suffix back with ``pool.truncate``.
        """
        L = self.pool.lens[req.seq_id]
        m = 1 + len(draft)
        ps = self.pool.page_size
        for pidx in range(L // ps, (L + m - 1) // ps + 1):
            self.pool.ensure_writable(req.seq_id, pidx)
        with self._mesh_scope():
            logits = sd.paged_chunk_forward(
                self.params, self.cfg, self.pool, req.seq_id,
                [req.tokens[-1]] + draft, L,
                pages_per_step=self.pages_per_step, logits="all")
        return np.asarray(logits[0], np.float32)

    def _spec_one(self, req: Request) -> None:
        """One draft–verify–rollback step for one active sequence."""
        remaining = req.max_new_tokens - len(req.tokens)
        gamma = min(self.spec_gamma, remaining - 1)
        draft, draft_q = ([], None)
        if gamma > 0:
            # drafting always runs replicated (the verify forward below is
            # the only mesh-parallel part of a speculative step); the draft
            # reservation covers the largest window auto-tuning could pick
            gamma_cap = max(self.spec_gamma, max(autotune.SPEC_GAMMAS))
            draft, draft_q = self.drafter.propose(
                req.seq_id, list(req.prompt_tokens) + req.tokens, gamma,
                reserve_tokens=req.reserve_tokens + gamma_cap + 1)
        L = self.pool.lens[req.seq_id]
        rows = self._spec_verify(req, draft)
        n_acc, emitted = sd.accept_speculative(
            rows, draft, draft_q, sample=self.sample,
            temperature=self.temperature, key=self.key, seq_id=req.seq_id,
            start_index=len(req.tokens))
        # the cache must hold everything but the last emitted token
        self.pool.truncate(req.seq_id, L + n_acc + 1)
        req.tokens.extend(emitted)
        req.spec.add(len(draft), n_acc, len(emitted))
        self.spec_totals.add(len(draft), n_acc, len(emitted))
        if len(req.tokens) >= req.max_new_tokens:
            self._finish(req)
        else:
            self.active.append(req)

    def _spec_step(self) -> None:
        """Draft–verify every active sequence (replaces the ragged decode)."""
        reqs = list(self.active)
        self.active = []
        for r in reqs:
            self._spec_one(r)
        if (self._spec_auto and self.spec_totals.steps
                - self._spec_last_tune >= self.SPEC_RETUNE_EVERY):
            self._spec_last_tune = self.spec_totals.steps
            self.spec_gamma = autotune.get_spec_gamma(
                self.spec_totals.acceptance_rate,
                draft_cost=self.drafter.cost_ratio)

    def spec_summary(self) -> Dict:
        """Aggregate + per-request draft/verify stats (finished AND
        in-flight requests, so mid-serve polling sees every sequence the
        aggregate counters cover)."""
        reqs = list(self.finished.values()) + self.active \
            + list(self.prefilling) + list(self.waiting)
        per = {r.seq_id: r.spec.summary()
               for r in sorted(reqs, key=lambda r: r.seq_id)}
        out = self.spec_totals.summary()
        out.update(enabled=self.drafter is not None,
                   gamma=self.spec_gamma if self.drafter is not None else 0,
                   per_request=per)
        return out

    # -- driving ---------------------------------------------------------
    def step(self) -> bool:
        """Admit what fits, one prefill chunk, one ragged decode step.

        Returns True while work remains. Prefill chunks and decode steps
        interleave 1:1 under the chunk token budget, so time-to-first-token
        for queued prompts and inter-token latency for running sequences
        both stay bounded regardless of prompt length.
        """
        self._admit()
        if self.prefilling:
            with self._mesh_scope():
                self._prefill_step()
        if self.active:
            if self.drafter is not None:
                self._spec_step()        # wraps only the verify in the mesh
            else:
                with self._mesh_scope():
                    self._decode()
        return bool(self.active or self.waiting or self.prefilling)

    def run(self) -> Dict[int, List[int]]:
        """Drain all queued/active requests; {seq_id: generated tokens}."""
        while self.step():
            pass
        return {sid: list(r.tokens) for sid, r in self.finished.items()}


# ---------------------------------------------------------------------------
# Batched generation entrypoints
# ---------------------------------------------------------------------------
def _generate_dense(params, cfg: ModelConfig, prompt: jax.Array, *,
                    steps: int, key, sample: str, temperature: float,
                    max_len: Optional[int], kv_dtype: Optional[str]):
    """Legacy dense-slab loop (hybrid/recurrent mixers carry non-KV state)."""
    b, s = prompt.shape[:2]
    max_len = max_len or (s + steps)
    caches = init_serve_caches(cfg, b, max_len, kv_dtype=kv_dtype)
    prefill = build_prefill_step(cfg)
    decode = build_decode_step(cfg, sample=sample, temperature=temperature)
    last, caches = prefill(params, prompt, caches)
    tok = jnp.argmax(last.astype(jnp.float32), axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(steps - 1):
        k = None if key is None else jax.random.fold_in(key, i)
        tok, caches = decode(params, caches, tok, jnp.int32(s + i), k)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def generate(params, cfg: ModelConfig, prompt: jax.Array, *, steps: int,
             key=None, sample: str = "greedy", temperature: float = 1.0,
             max_len: Optional[int] = None, kv_dtype: Optional[str] = None,
             page_size: Optional[int] = None, mesh=None,
             tp_int8_reduce: bool = False,
             retain_pages: Optional[int] = None,
             spec: Optional[sd.SpecConfig] = None):
    """Batched generation: prompt (B, S) → (B, steps) new tokens.

    All-attention models run on the continuous-batching engine (paged pool;
    pages are int8 when ``kv_dtype='int8'``, else the model dtype). Models
    with SSM/RWKV mixers fall back to the dense-slab loop (``spec`` is
    ignored there — speculation needs the paged cache's rollback). ``mesh``
    turns on tensor-parallel serving and ``spec`` turns on speculative
    decoding (see :class:`ContinuousBatchingEngine`).
    """
    b, s = prompt.shape[:2]
    if (cfg.embedding_inputs
            or any(cfg.mixer_of(i) != "attn" for i in range(cfg.n_layers))):
        return _generate_dense(params, cfg, prompt, steps=steps, key=key,
                               sample=sample, temperature=temperature,
                               max_len=max_len, kv_dtype=kv_dtype)
    ps = page_size or kvc.DEFAULT_PAGE_SIZE
    eng = ContinuousBatchingEngine(
        params, cfg, kv_dtype=kv_dtype, page_size=ps,
        capacity_tokens=b * kvc.round_up(s + steps, ps),
        sample=sample, temperature=temperature, key=key,
        mesh=mesh, tp_int8_reduce=tp_int8_reduce, retain_pages=retain_pages,
        spec=spec)
    sids = [eng.submit(prompt[i], steps) for i in range(b)]
    outs = eng.run()
    return jnp.asarray([outs[sid] for sid in sids], jnp.int32)
