"""Serving steps: batched prefill + single-token decode with KV cache.

This is where the CAMP technique earns its keep at scale: decode is
memory-roofline-bound, so int8/int4 weights (``cfg.qmode``) and optionally
int8 KV cache cut the dominant roofline term 2–4×. llama4-maverick-400B
*only* fits the single-pod decode cell quantized (see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_caches


def init_serve_caches(cfg: ModelConfig, batch: int, max_len: int,
                      kv_dtype: Optional[str] = None):
    """KV/state caches; ``kv_dtype='int8'`` stores attention KV quantized.

    int8 KV uses a fixed per-cache scale folded at write/read (symmetric,
    scale baked into the dtype conversion here since rope output is O(1);
    a per-block scale variant is a straightforward extension).
    """
    caches = init_caches(cfg, batch, max_len)
    if kv_dtype == "int8":
        def conv(c):
            if isinstance(c, dict) and "k" in c and "v" in c:
                return {"k": jnp.zeros(c["k"].shape, jnp.int8),
                        "v": jnp.zeros(c["v"].shape, jnp.int8)}
            return c
        caches = [{k: conv(v) for k, v in layer.items()} for layer in caches]
    return caches


_QMODE_KIND = {"w8a8": "i8", "w4a8": "w4", "w4a4": "a4w4"}


def warm_gemm_autotune(cfg: ModelConfig, *, batch_sizes=(1, 8, 32),
                       prefill_len: int = 0, measure=None):
    """Pre-tune CAMP GEMM blocks for the dense transformer linears.

    Decode runs one token per sequence (M = batch) and prefill runs
    M = batch × prompt_len; both hit the same (K, N) weight shapes. Tuning
    them here — measured on a live TPU, analytic elsewhere — populates the
    persistent autotune cache so the request path never tunes. Covered:
    attention q/kv/out, dense MLP up/gate/down, and the untied lm head.
    Mixer-specific extras (SSM/RWKV projections) and MoE experts are not
    enumerated — the former cold-tune on first sight (instant off-TPU), the
    latter run through einsum, not the CAMP GEMM cache.

    Returns [((m, n, k), (bm, bn, bk)), ...] for logging.
    """
    kind = _QMODE_KIND.get(cfg.qmode)
    if kind is None:  # 'none' / weight-only: bf16 matmul, nothing to tune
        return []
    import jax.numpy as jnp
    from repro.core import autotune
    a_in_bytes = jnp.dtype(cfg.dtype).itemsize  # must match the request path
    d, hd = cfg.d_model, cfg.hd
    proj = {
        (d, hd * cfg.n_heads), (d, hd * cfg.n_kv_heads),   # q / kv proj
        (hd * cfg.n_heads, d),                             # attn out
        (d, cfg.d_ff), (cfg.d_ff, d),                      # mlp up/gate/down
    }
    if not cfg.tie_embeddings:
        proj.add((d, cfg.vocab_size))                      # quantized lm head
    ms = sorted({b * max(prefill_len, 1) for b in batch_sizes} |
                set(batch_sizes))
    out = []
    for m in ms:
        for (k, n) in sorted(proj):
            blk = autotune.tune(kind, m, n, k, fused=True,
                                a_in_bytes=a_in_bytes, measure=measure,
                                save=False)
            out.append(((m, n, k), blk))
    autotune.flush()  # one disk write for the whole warmup
    return out


def build_prefill_step(cfg: ModelConfig, *, max_len: Optional[int] = None):
    """(params, inputs, caches) → (last_token_logits, caches)."""

    def prefill_step(params, inputs, caches):
        # last_logits_only: a 32k prefill needs the head at ONE position,
        # not a (B, 32768, V) logits tensor.
        logits, caches, _ = forward(params, cfg, inputs, caches=caches,
                                    last_logits_only=True)
        return logits[:, -1], caches

    return prefill_step


def build_decode_step(cfg: ModelConfig, *, sample: str = "greedy",
                      temperature: float = 1.0):
    """(params, caches, token, pos, key) → (next_token, caches).

    ``token``: (B, 1) int32; ``pos``: scalar int32 current position.
    """

    def decode_step(params, caches, token, pos, key=None):
        logits, caches, _ = forward(params, cfg, token, caches=caches,
                                    cache_pos=pos)
        last = logits[:, -1].astype(jnp.float32)
        if sample == "greedy":
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        return nxt[:, None].astype(jnp.int32), caches

    return decode_step


def generate(params, cfg: ModelConfig, prompt: jax.Array, *, steps: int,
             key=None, sample: str = "greedy", temperature: float = 1.0,
             max_len: Optional[int] = None):
    """Simple batched generation loop (prefill + python decode loop)."""
    b, s = prompt.shape[:2]
    max_len = max_len or (s + steps)
    caches = init_serve_caches(cfg, b, max_len)
    prefill = build_prefill_step(cfg)
    decode = build_decode_step(cfg, sample=sample, temperature=temperature)
    last, caches = prefill(params, prompt, caches)
    tok = jnp.argmax(last.astype(jnp.float32), axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(steps - 1):
        k = None if key is None else jax.random.fold_in(key, i)
        tok, caches = decode(params, caches, tok, jnp.int32(s + i), k)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
