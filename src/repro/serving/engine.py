"""Serving engine: continuous batching over a paged, quantized KV cache.

This is where the CAMP technique earns its keep at scale: decode is
memory-roofline-bound, so int8/int4 weights (``cfg.qmode``) cut the weight
stream and the paged int8 KV cache (:mod:`repro.serving.kv_cache`) cuts the
cache stream — decode reads only the pages a sequence occupies, at one byte
per element, dequantized in-register by the paged-attention kernel.

Two serving modes:

* :class:`ContinuousBatchingEngine` — sequences are admitted and finished
  **mid-flight** over a shared page pool: ``submit()`` queues a request,
  every ``step()`` first admits whatever fits (prefill runs densely per
  request, then its KV is quantized page-by-page into the pool) and then
  runs one ragged decode over all active sequences (per-sequence positions
  and block tables; no padding to a common length). Finished sequences
  return their pages to the free list immediately, so a long request no
  longer holds the batch hostage. ``generate()`` is a thin batch wrapper on
  top.
* the dense-slab path (``build_prefill_step`` / ``build_decode_step``) —
  the degenerate single-block-table case, kept for hybrid/recurrent mixers
  (SSM/RWKV carry non-KV state) and for the multi-pod dry-run cells.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_caches
from repro.serving import kv_cache as kvc


def init_serve_caches(cfg: ModelConfig, batch: int, max_len: int,
                      kv_dtype: Optional[str] = None):
    """Dense KV/state caches; ``kv_dtype='int8'`` stores attention KV
    quantized with **per-page dynamic scales** (amax/127 of each page —
    scale handling lives in :mod:`repro.serving.kv_cache`, shared with the
    paged pool)."""
    return init_caches(cfg, batch, max_len, kv_dtype=kv_dtype)


_QMODE_KIND = {"w8a8": "i8", "w4a8": "w4", "w4a4": "a4w4"}


def warm_gemm_autotune(cfg: ModelConfig, *, batch_sizes=(1, 8, 32),
                       prefill_len: int = 0, measure=None):
    """Pre-tune CAMP GEMM blocks for the transformer's serving linears.

    Decode runs one token per sequence (M = batch) and prefill runs
    M = batch × prompt_len; both hit the same (K, N) weight shapes. Tuning
    them here — measured on a live TPU, analytic elsewhere — populates the
    persistent autotune cache so the request path never tunes. Covered:
    attention q/kv/out, dense MLP up/gate/down, MoE expert up/gate/down
    (``(d, expert_ff)`` / ``(expert_ff, d)``), and the untied lm head.
    Note: today's expert compute is a batched einsum that bypasses the CAMP
    GEMM dispatch — the expert entries pre-populate the cache for the
    planned per-expert CAMP routing (see ROADMAP follow-ups), they are not
    read by the current einsum path. Mixer-specific extras (SSM/RWKV
    projections) still cold-tune on first sight.

    Returns [((m, n, k), (bm, bn, bk)), ...] for logging.
    """
    kind = _QMODE_KIND.get(cfg.qmode)
    if kind is None:  # 'none' / weight-only: bf16 matmul, nothing to tune
        return []
    a_in_bytes = jnp.dtype(cfg.dtype).itemsize  # must match the request path
    d, hd = cfg.d_model, cfg.hd
    proj = {
        (d, hd * cfg.n_heads), (d, hd * cfg.n_kv_heads),   # q / kv proj
        (hd * cfg.n_heads, d),                             # attn out
        (d, cfg.d_ff), (cfg.d_ff, d),                      # mlp up/gate/down
    }
    if cfg.moe_experts:
        proj |= {(d, cfg.expert_ff), (cfg.expert_ff, d)}   # expert up/gate/down
    if not cfg.tie_embeddings:
        proj.add((d, cfg.vocab_size))                      # quantized lm head
    ms = sorted({b * max(prefill_len, 1) for b in batch_sizes} |
                set(batch_sizes))
    out = []
    for m in ms:
        for (k, n) in sorted(proj):
            blk = autotune.tune(kind, m, n, k, fused=True,
                                a_in_bytes=a_in_bytes, measure=measure,
                                save=False)
            out.append(((m, n, k), blk))
    autotune.flush()  # one disk write for the whole warmup
    return out


def build_prefill_step(cfg: ModelConfig, *, max_len: Optional[int] = None):
    """(params, inputs, caches) → (last_token_logits, caches)."""

    def prefill_step(params, inputs, caches):
        # last_logits_only: a 32k prefill needs the head at ONE position,
        # not a (B, 32768, V) logits tensor.
        logits, caches, _ = forward(params, cfg, inputs, caches=caches,
                                    last_logits_only=True)
        return logits[:, -1], caches

    return prefill_step


def build_decode_step(cfg: ModelConfig, *, sample: str = "greedy",
                      temperature: float = 1.0):
    """(params, caches, token, pos, key) → (next_token, caches).

    ``token``: (B, 1) int32; ``pos``: scalar int32 current position.
    """

    def decode_step(params, caches, token, pos, key=None):
        logits, caches, _ = forward(params, cfg, token, caches=caches,
                                    cache_pos=pos)
        last = logits[:, -1].astype(jnp.float32)
        if sample == "greedy":
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        return nxt[:, None].astype(jnp.int32), caches

    return decode_step


# ---------------------------------------------------------------------------
# Continuous batching over the shared page pool
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    """One in-flight generation request."""
    seq_id: int
    prompt: jax.Array                    # (S,) int32
    max_new_tokens: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def reserve_tokens(self) -> int:
        return int(self.prompt.shape[0]) + self.max_new_tokens


class ContinuousBatchingEngine:
    """Admit/finish sequences mid-flight over a shared paged KV pool.

    Scheduling is conservative: a request is admitted only when the pool can
    reserve its worst-case page count (prompt + max_new_tokens), so an
    admitted sequence can never stall mid-decode waiting for pages. Each
    ``step()``:

    1. admits queued requests in FIFO order while reservations fit — each
       admission runs a batch-1 dense prefill (exact, model dtype) and
       quantizes the resulting KV page-by-page into the pool;
    2. runs **one ragged decode** over every active sequence: per-sequence
       positions, per-sequence block tables, one forward pass — attention
       goes through the paged int8 kernel, so a step's HBM traffic is the
       pages actually occupied, not ``batch × max_len``;
    3. retires sequences that hit their token budget and returns their pages
       to the free list, making room for the next admission.

    Per-sequence results are independent of co-scheduling: pages are owned
    exclusively, per-page scales depend only on a page's own content,
    attention is masked per sequence length, and sampling keys are derived
    per (seq_id, token index) — a sequence decodes identically whether it
    runs alone or inside a changing batch.
    """

    def __init__(self, params, cfg: ModelConfig, *,
                 kv_dtype: Optional[str] = "int8",
                 page_size: Optional[int] = None,
                 capacity_tokens: Optional[int] = None,
                 sample: str = "greedy", temperature: float = 1.0,
                 key: Optional[jax.Array] = None):
        mixers = {cfg.mixer_of(i) for i in range(cfg.n_layers)}
        if mixers != {"attn"}:
            raise ValueError(
                f"continuous batching requires attention mixers, got {mixers}"
                " (hybrid/recurrent models use the dense serving path)")
        self.params, self.cfg = params, cfg
        self.sample, self.temperature = sample, temperature
        self.key = jax.random.PRNGKey(0) if key is None else key
        # page size comes from the persistent autotune cache (analytic v5e
        # model off-TPU) unless pinned by the caller
        ps = page_size or autotune.get_page_size(
            cfg.n_kv_heads, cfg.hd, mean_len=max(cfg.max_seq_len // 2, 128))
        capacity_tokens = capacity_tokens or 8 * cfg.max_seq_len
        self.pool = kvc.PagePool(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
            num_pages=-(-capacity_tokens // ps), page_size=ps,
            quantized=(kv_dtype == "int8"), dtype=jnp.dtype(cfg.dtype))
        self.waiting: collections.deque = collections.deque()
        self.active: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._next_id = 0

    # -- request lifecycle ----------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> int:
        """Queue a prompt; returns its sequence id."""
        prompt = jnp.asarray(prompt, jnp.int32).reshape(-1)
        seq_id = self._next_id
        self._next_id += 1
        self.waiting.append(Request(seq_id, prompt, max_new_tokens))
        return seq_id

    def _sample_tokens(self, logits: jax.Array,
                       reqs: List[Request]) -> jax.Array:
        """logits (B, V) → (B,) int32; rows align with ``reqs``.

        Non-greedy keys are folded from (engine key, seq_id, token index),
        never from a shared stream — so sampled tokens don't depend on which
        other sequences happen to share the batch.
        """
        last = logits.astype(jnp.float32)
        if self.sample == "greedy":
            return jnp.argmax(last, axis=-1)
        keys = jnp.stack([
            jax.random.fold_in(jax.random.fold_in(self.key, r.seq_id),
                               len(r.tokens))
            for r in reqs])
        return jax.vmap(
            lambda k, l: jax.random.categorical(k, l / self.temperature)
        )(keys, last)

    def _finish(self, req: Request) -> None:
        self.pool.release(req.seq_id)
        req.done = True
        self.finished[req.seq_id] = req

    def _prefill(self, req: Request) -> None:
        """Batch-1 dense prefill, then quantize KV into the pool's pages."""
        s = int(req.prompt.shape[0])
        self.pool.reserve(req.seq_id, req.reserve_tokens)
        caches = init_caches(self.cfg, 1, s)
        logits, caches, _ = forward(self.params, self.cfg, req.prompt[None],
                                    caches=caches, last_logits_only=True)
        for i, layer in enumerate(caches):
            dense = layer["attn"]
            self.pool.ingest(req.seq_id, i, dense.k, dense.v)
        req.tokens.append(int(self._sample_tokens(logits[:, -1], [req])[0]))
        if len(req.tokens) >= req.max_new_tokens:
            self._finish(req)
        else:
            self.active.append(req)

    def _admit(self) -> None:
        while self.waiting:
            nxt: Request = self.waiting[0]
            if not self.pool.can_reserve(nxt.reserve_tokens):
                if not self.active:
                    raise RuntimeError(
                        f"request {nxt.seq_id} needs "
                        f"{self.pool.pages_for(nxt.reserve_tokens)} pages; "
                        f"pool has {self.pool.num_pages} total")
                break
            self.waiting.popleft()
            self._prefill(nxt)

    def _decode(self) -> None:
        """One ragged decode step over all active sequences."""
        reqs = list(self.active)
        seq_ids = [r.seq_id for r in reqs]
        tokens = jnp.asarray([[r.tokens[-1]] for r in reqs], jnp.int32)
        tables, lengths = self.pool.batch_tables(seq_ids)
        caches = [{"attn": self.pool.layer_cache(i, tables, lengths)}
                  for i in range(self.cfg.n_layers)]
        logits, new_caches, _ = forward(self.params, self.cfg, tokens,
                                        positions=lengths[:, None],
                                        caches=caches)
        for i, layer in enumerate(new_caches):
            self.pool.writeback(i, layer["attn"])
        for r in reqs:
            self.pool.lens[r.seq_id] += 1
        nxt = np.asarray(self._sample_tokens(logits[:, -1], reqs))
        self.active = []
        for r, t in zip(reqs, nxt):
            r.tokens.append(int(t))
            if len(r.tokens) >= r.max_new_tokens:
                self._finish(r)
            else:
                self.active.append(r)

    # -- driving ---------------------------------------------------------
    def step(self) -> bool:
        """Admit what fits, then one decode step. True while work remains."""
        self._admit()
        if self.active:
            self._decode()
        return bool(self.active or self.waiting)

    def run(self) -> Dict[int, List[int]]:
        """Drain all queued/active requests; {seq_id: generated tokens}."""
        while self.step():
            pass
        return {sid: list(r.tokens) for sid, r in self.finished.items()}


# ---------------------------------------------------------------------------
# Batched generation entrypoints
# ---------------------------------------------------------------------------
def _generate_dense(params, cfg: ModelConfig, prompt: jax.Array, *,
                    steps: int, key, sample: str, temperature: float,
                    max_len: Optional[int], kv_dtype: Optional[str]):
    """Legacy dense-slab loop (hybrid/recurrent mixers carry non-KV state)."""
    b, s = prompt.shape[:2]
    max_len = max_len or (s + steps)
    caches = init_serve_caches(cfg, b, max_len, kv_dtype=kv_dtype)
    prefill = build_prefill_step(cfg)
    decode = build_decode_step(cfg, sample=sample, temperature=temperature)
    last, caches = prefill(params, prompt, caches)
    tok = jnp.argmax(last.astype(jnp.float32), axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(steps - 1):
        k = None if key is None else jax.random.fold_in(key, i)
        tok, caches = decode(params, caches, tok, jnp.int32(s + i), k)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def generate(params, cfg: ModelConfig, prompt: jax.Array, *, steps: int,
             key=None, sample: str = "greedy", temperature: float = 1.0,
             max_len: Optional[int] = None, kv_dtype: Optional[str] = None,
             page_size: Optional[int] = None):
    """Batched generation: prompt (B, S) → (B, steps) new tokens.

    All-attention models run on the continuous-batching engine (paged pool;
    pages are int8 when ``kv_dtype='int8'``, else the model dtype). Models
    with SSM/RWKV mixers fall back to the dense-slab loop.
    """
    b, s = prompt.shape[:2]
    if (cfg.embedding_inputs
            or any(cfg.mixer_of(i) != "attn" for i in range(cfg.n_layers))):
        return _generate_dense(params, cfg, prompt, steps=steps, key=key,
                               sample=sample, temperature=temperature,
                               max_len=max_len, kv_dtype=kv_dtype)
    ps = page_size or kvc.DEFAULT_PAGE_SIZE
    eng = ContinuousBatchingEngine(
        params, cfg, kv_dtype=kv_dtype, page_size=ps,
        capacity_tokens=b * kvc.round_up(s + steps, ps),
        sample=sample, temperature=temperature, key=key)
    sids = [eng.submit(prompt[i], steps) for i in range(b)]
    outs = eng.run()
    return jnp.asarray([outs[sid] for sid in sids], jnp.int32)
