"""Paged, quantized KV cache — the CAMP storage/compute split applied to
the serving cache itself.

Decode is memory-roofline-bound: every generated token re-reads the whole
KV cache. Two orthogonal reductions live here:

* **int8 storage with token-granular dynamic scales** — each
  (page, kv-head, token) row carries its own scale (amax/127 over the head
  dim), replacing the old global hard-coded ``KV_INT8_SCALE``. Keys after
  rope/qk-norm are O(1) but not uniformly so across layers, heads and
  positions; dynamic per-token scales keep the quantization step
  proportional to the *local* magnitude. Token granularity also makes every
  page **write-once**: a token's stored bytes are a pure function of its own
  k/v values, never requantized when a neighbour lands in the same page —
  so the cache state after N tokens is bit-identical no matter how the
  writes were grouped (single appends, prefill chunks, or speculative
  panels), which is what lets speculative decoding roll a rejected draft
  suffix back (:meth:`PagePool.truncate`) without perturbing the kept
  prefix.
* **paging** — KV lives in fixed-size pages owned by a shared pool;
  per-sequence block tables map logical positions to page slots. Decode
  reads only the pages a sequence actually occupies instead of a
  ``(batch, max_len)`` slab, and a continuous-batching engine can admit /
  finish sequences mid-flight by moving pages between the free list and
  block tables (the vLLM PagedAttention memory model).

The pool is the **only** KV representation in the serving engine — prefill
writes straight into pages (chunk by chunk, no dense staging slab) and decode
appends to them. Pages are **reference counted**: sequences whose prompts
share a prefix share the physical pages holding it (a trie keyed by
page-sized token chunks maps prompt prefixes to page chains), and
:meth:`PagePool.fork` clones a sequence in O(1) by increffing its table.
Writes go through :meth:`PagePool.ensure_writable`, which copies a page only
on the first divergent write (copy-on-write). Trie-indexed prefix pages
whose last reference dies are *retained* in a bounded LRU (evicted under
pool pressure) so a re-submitted prompt re-shares them instead of
re-prefilling.

Under tensor-parallel serving the pool's page storage is **head-sharded**
over a mesh's ``model`` axis (``PagePool(mesh=...)``): each device holds its
``n_kv_heads / model_shards`` heads of every page, per-token scales shard
alongside, and all allocator/trie/block-table state stays replicated
host-side control metadata.

Cache types:

* :class:`DenseKVCache` — the (B, KV, T, hd) slab, used by the legacy
  dense serving path (SSM/RWKV mixers, multi-pod dry-run cells) and
  training. Quantized variants view the slab as ``T // page_size`` pages so
  the scale handling is identical to the pool's.
* :class:`PagePool` — host-side page allocator: per-layer page arrays, a
  free list, per-slot refcounts, per-sequence block tables and lengths,
  and the prefix-sharing trie.
* :class:`PagedDecodeCache` — a per-layer, per-decode-step pytree view
  (pages + scales + batched block table + lengths) that flows through
  ``forward``; :mod:`repro.models.attention` appends to it and runs the
  paged-attention kernel over it.
* :class:`PagedPrefillCache` — a per-layer, per-prefill-chunk pytree view
  (pages + scales + one sequence's block table + the chunk's start token):
  :mod:`repro.models.attention` quantizes the chunk's KV into the owned
  pages and runs the chunked paged-prefill kernel
  (:mod:`repro.kernels.paged_prefill`) over the whole cached prefix.

All int8 conversion in the repo funnels through :func:`quantize_int8` /
:func:`dequantize_int8` here (previously duplicated between
``models.attention._to_cache_dtype`` and ``serving.engine.init_serve_caches``).
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.sharding import effective_model_shards

INT8_AMAX = 127.0
SCALE_EPS = 1e-8          # floor so all-zero pages dequantize to exact zeros
DEFAULT_PAGE_SIZE = 16


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# int8 conversion — the one place scale handling lives
# ---------------------------------------------------------------------------
def int8_scale(x: jax.Array, axes) -> jax.Array:
    """Symmetric dynamic scale: amax over ``axes`` / 127, floored."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes)
    return jnp.maximum(amax / INT8_AMAX, SCALE_EPS)


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Round-to-nearest symmetric int8; ``scale`` broadcasts against ``x``."""
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -INT8_AMAX, INT8_AMAX).astype(jnp.int8)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _chunk_to_pages(x: jax.Array, n_pages: int, page_size: int) -> jax.Array:
    """(1, KV, S, hd) float → (n_pages, KV, page_size, hd) f32 page block,
    zero-padded past S (the one pipeline all pool page-writes go through)."""
    kv, s, hd = x.shape[1], x.shape[2], x.shape[3]
    pad = n_pages * page_size - s
    x = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))[0]
    return jnp.swapaxes(x.reshape(kv, n_pages, page_size, hd), 0, 1)


def _quantize_page_block(xp: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(np, KV, ps, hd) f32 → (int8 payload, (np, KV, ps) per-token scales).

    One scale per (page, head, token) row, computed over the head dim only —
    a token's stored bytes depend on nothing but its own values (write-once
    pages; see the module docstring)."""
    sc = int8_scale(xp, axes=(3,))
    return quantize_int8(xp, sc[..., None]), sc


def _quantize_pages(x: jax.Array, page_size: int) -> Tuple[jax.Array, jax.Array]:
    """x: (..., T, hd) with T a page multiple → (int8 (..., T, hd),
    scales (..., T // page_size)) — one scale per (lead..., page)."""
    lead = x.shape[:-2]
    t, hd = x.shape[-2:]
    n_pages = t // page_size
    paged = x.reshape(*lead, n_pages, page_size, hd)
    scale = int8_scale(paged, axes=(-2, -1))                 # (..., n_pages)
    q = quantize_int8(paged, scale[..., None, None])
    return q.reshape(*lead, t, hd), scale


# ---------------------------------------------------------------------------
# Dense slab cache (prefill + legacy decode; training path unchanged)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DenseKVCache:
    """(B, KV, T, hd) KV slab; int8 storage carries per-page scales.

    ``k_scale``/``v_scale``: (B, KV, T // page_size) f32, or None for float
    storage. Registered as a pytree (page_size is static aux data) so caches
    flow through ``jax.eval_shape`` / shardings / jit unchanged.
    """
    k: jax.Array
    v: jax.Array
    k_scale: Optional[jax.Array]
    v_scale: Optional[jax.Array]
    page_size: int

    # -- construction ----------------------------------------------------
    @classmethod
    def init(cls, batch: int, n_kv_heads: int, max_len: int, head_dim: int,
             dtype, *, quantized: bool = False,
             page_size: int = DEFAULT_PAGE_SIZE) -> "DenseKVCache":
        if quantized:
            t = round_up(max_len, page_size)
            shape = (batch, n_kv_heads, t, head_dim)
            sshape = (batch, n_kv_heads, t // page_size)
            return cls(k=jnp.zeros(shape, jnp.int8),
                       v=jnp.zeros(shape, jnp.int8),
                       k_scale=jnp.full(sshape, SCALE_EPS, jnp.float32),
                       v_scale=jnp.full(sshape, SCALE_EPS, jnp.float32),
                       page_size=page_size)
        shape = (batch, n_kv_heads, max_len, head_dim)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   k_scale=None, v_scale=None, page_size=page_size)

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def max_len(self) -> int:
        return self.k.shape[2]

    # -- writes ----------------------------------------------------------
    def write_prefill(self, k_t: jax.Array, v_t: jax.Array) -> "DenseKVCache":
        """Fill positions [0, S) from (B, KV, S, hd) new keys/values."""
        if not self.quantized:
            k = jax.lax.dynamic_update_slice(
                self.k, k_t.astype(self.k.dtype), (0, 0, 0, 0))
            v = jax.lax.dynamic_update_slice(
                self.v, v_t.astype(self.v.dtype), (0, 0, 0, 0))
            return dataclasses.replace(self, k=k, v=v)
        ps = self.page_size
        s = k_t.shape[2]
        pad = round_up(s, ps) - s
        if pad:
            width = ((0, 0), (0, 0), (0, pad), (0, 0))
            k_t = jnp.pad(k_t.astype(jnp.float32), width)
            v_t = jnp.pad(v_t.astype(jnp.float32), width)
        kq, ks = _quantize_pages(k_t, ps)
        vq, vs = _quantize_pages(v_t, ps)
        return dataclasses.replace(
            self,
            k=jax.lax.dynamic_update_slice(self.k, kq, (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(self.v, vq, (0, 0, 0, 0)),
            k_scale=jax.lax.dynamic_update_slice(self.k_scale, ks, (0, 0, 0)),
            v_scale=jax.lax.dynamic_update_slice(self.v_scale, vs, (0, 0, 0)))

    def append(self, k_t: jax.Array, v_t: jax.Array, pos) -> "DenseKVCache":
        """Write one token (B, KV, 1, hd) at (traced) position ``pos``."""
        if not self.quantized:
            k = jax.lax.dynamic_update_slice(
                self.k, k_t.astype(self.k.dtype), (0, 0, pos, 0))
            v = jax.lax.dynamic_update_slice(
                self.v, v_t.astype(self.v.dtype), (0, 0, pos, 0))
            return dataclasses.replace(self, k=k, v=v)
        ps = self.page_size
        b, kv, _, hd = self.k.shape
        page = pos // ps
        start = page * ps
        off = pos - start

        def upd(slab, scales, new):
            pageq = jax.lax.dynamic_slice(slab, (0, 0, start, 0),
                                          (b, kv, ps, hd))
            sc = jax.lax.dynamic_slice(scales, (0, 0, page), (b, kv, 1))
            pf = pageq.astype(jnp.float32) * sc[..., None]       # (B,KV,ps,hd)
            idx = jnp.arange(ps)
            keep = (idx < off)[None, None, :, None]
            ins = (idx == off)[None, None, :, None]
            pf = jnp.where(keep, pf, 0.0) + new.astype(jnp.float32) * ins
            sc_new = int8_scale(pf, axes=(2, 3))[..., None]      # (B,KV,1)
            pq = quantize_int8(pf, sc_new[..., None])
            return (jax.lax.dynamic_update_slice(slab, pq, (0, 0, start, 0)),
                    jax.lax.dynamic_update_slice(scales, sc_new, (0, 0, page)))

        k, k_scale = upd(self.k, self.k_scale, k_t)
        v, v_scale = upd(self.v, self.v_scale, v_t)
        return dataclasses.replace(self, k=k, v=v, k_scale=k_scale,
                                   v_scale=v_scale)

    # -- reads -----------------------------------------------------------
    def read(self, out_dtype) -> Tuple[jax.Array, jax.Array]:
        """Dequantized contents: ((B, T, KV, hd), (B, T, KV, hd))."""
        if not self.quantized:
            return (jnp.swapaxes(self.k, 1, 2).astype(out_dtype),
                    jnp.swapaxes(self.v, 1, 2).astype(out_dtype))
        b, kv, t, hd = self.k.shape
        ps = self.page_size

        def deq(slab, scales):
            paged = slab.reshape(b, kv, t // ps, ps, hd)
            f = dequantize_int8(paged, scales[..., None, None], out_dtype)
            return jnp.swapaxes(f.reshape(b, kv, t, hd), 1, 2)

        return deq(self.k, self.k_scale), deq(self.v, self.v_scale)


def _dense_flatten(c: DenseKVCache):
    return (c.k, c.v, c.k_scale, c.v_scale), (c.page_size,)


def _dense_unflatten(aux, children):
    k, v, ks, vs = children
    return DenseKVCache(k=k, v=v, k_scale=ks, v_scale=vs, page_size=aux[0])


jax.tree_util.register_pytree_node(DenseKVCache, _dense_flatten,
                                   _dense_unflatten)


# ---------------------------------------------------------------------------
# Paged decode view (flows through forward() during a ragged decode step)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PagedDecodeCache:
    """One attention layer's paged KV for one batched decode step.

    ``k_pages``/``v_pages``: (P, KV, page_size, hd) pool pages (int8 when
    quantized, else the model dtype). ``k_scale``/``v_scale``:
    (P, KV, page_size) f32 per-token scales (None for float pages).
    ``tables``: (B, max_pages) int32 block table (rows padded with slot 0
    past a sequence's last page). ``lengths``: (B,) int32 tokens currently
    cached per sequence.
    """
    k_pages: jax.Array
    v_pages: jax.Array
    k_scale: Optional[jax.Array]
    v_scale: Optional[jax.Array]
    tables: jax.Array
    lengths: jax.Array

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def append(self, k_new: jax.Array, v_new: jax.Array) -> "PagedDecodeCache":
        """Append one token per sequence: k_new/v_new (B, KV, hd).

        Token-granular scales make this a pure **write-once** scatter: the
        new token's bytes and scale land in its (page, offset) row and no
        neighbouring token is ever requantized. Stale rows past a sequence's
        length (from evicted occupants or rolled-back speculation) are never
        read — every consumer masks by ``lengths``. Sequences own disjoint
        pages, so the batched scatter never collides.
        """
        ps = self.page_size
        pidx = self.lengths // ps                                  # (B,)
        slot = jnp.take_along_axis(self.tables, pidx[:, None], axis=1)[:, 0]
        off = self.lengths % ps                                    # (B,)

        def upd(pages, scales, new):
            if scales is None:
                return pages.at[slot, :, off].set(new.astype(pages.dtype)), \
                    None
            sc = int8_scale(new, axes=(-1,))                       # (B, KV)
            q = quantize_int8(new, sc[..., None])
            return (pages.at[slot, :, off].set(q),
                    scales.at[slot, :, off].set(sc))

        k_pages, k_scale = upd(self.k_pages, self.k_scale, k_new)
        v_pages, v_scale = upd(self.v_pages, self.v_scale, v_new)
        return dataclasses.replace(self, k_pages=k_pages, v_pages=v_pages,
                                   k_scale=k_scale, v_scale=v_scale,
                                   lengths=self.lengths + 1)


def _paged_flatten(c: PagedDecodeCache):
    return (c.k_pages, c.v_pages, c.k_scale, c.v_scale, c.tables,
            c.lengths), ()


def _paged_unflatten(aux, children):
    kp, vp, ks, vs, tables, lengths = children
    return PagedDecodeCache(k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
                            tables=tables, lengths=lengths)


jax.tree_util.register_pytree_node(PagedDecodeCache, _paged_flatten,
                                   _paged_unflatten)


# ---------------------------------------------------------------------------
# Paged prefill view (flows through forward() during one prefill chunk)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PagedPrefillCache:
    """One attention layer's paged KV for one sequence's multi-token chunk.

    ``k_pages``/``v_pages``/``k_scale``/``v_scale``: the pool's per-layer
    arrays (see :class:`PagedDecodeCache`). ``table``: (max_pages,) int32 —
    this sequence's block table. ``q_start``: tokens already cached before
    this chunk (static). The prefill lane keeps it page-aligned (whole
    fresh pages per chunk); a **speculative verify panel** starts wherever
    decode left off — unaligned starts take the token-scatter write path,
    which lands each token's write-once bytes in its (page, offset) row
    without touching earlier tokens in a partial tail page.
    ``pages_per_step``: kv pages fetched per grid step by the prefill
    kernel (autotuned, static).
    """
    k_pages: jax.Array
    v_pages: jax.Array
    k_scale: Optional[jax.Array]
    v_scale: Optional[jax.Array]
    table: jax.Array
    q_start: int
    pages_per_step: int = 1

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def write_chunk(self, k_t: jax.Array, v_t: jax.Array) -> "PagedPrefillCache":
        """Quantize a chunk's KV (1, KV, C, hd) into pages [q_start, q_start+C).

        Every page row written here is exclusively owned — prefix-shared
        pages cover only the tokens the engine skipped, and the engine's
        speculative path crosses :meth:`PagePool.ensure_writable` first —
        so no COW happens inside this write. Page-aligned starts (the
        prefill lane) scatter whole pages at once; unaligned starts (a
        speculative verify panel resuming mid-page) scatter per token, so
        the earlier tokens of a partial tail page keep their write-once
        bytes. Either way each token is quantized exactly once, from its
        exact values, with its own scale — grouping never changes the
        stored bits.
        """
        ps = self.page_size
        c = k_t.shape[2]
        if self.q_start % ps == 0:
            p0 = self.q_start // ps
            n_w = -(-c // ps)
            slots = jax.lax.dynamic_slice(self.table, (p0,), (n_w,))

            def upd(pages, scales, x):
                xp = _chunk_to_pages(x, n_w, ps)
                if scales is None:
                    return pages.at[slots].set(xp.astype(pages.dtype)), None
                xq, sc = _quantize_page_block(xp)
                return pages.at[slots].set(xq), scales.at[slots].set(sc)
        else:
            pos = self.q_start + jnp.arange(c)
            slots = self.table[pos // ps]                          # (C,)
            offs = pos % ps                                        # (C,)

            def upd(pages, scales, x):
                tok = jnp.swapaxes(x[0], 0, 1)                     # (C, KV, hd)
                if scales is None:
                    return pages.at[slots, :, offs].set(
                        tok.astype(pages.dtype)), None
                sc = int8_scale(tok, axes=(-1,))                   # (C, KV)
                q = quantize_int8(tok.astype(jnp.float32), sc[..., None])
                return (pages.at[slots, :, offs].set(q),
                        scales.at[slots, :, offs].set(sc))

        k_pages, k_scale = upd(self.k_pages, self.k_scale, k_t)
        v_pages, v_scale = upd(self.v_pages, self.v_scale, v_t)
        return dataclasses.replace(self, k_pages=k_pages, v_pages=v_pages,
                                   k_scale=k_scale, v_scale=v_scale)


def _pprefill_flatten(c: PagedPrefillCache):
    return (c.k_pages, c.v_pages, c.k_scale, c.v_scale, c.table), \
        (c.q_start, c.pages_per_step)


def _pprefill_unflatten(aux, children):
    kp, vp, ks, vs, table = children
    return PagedPrefillCache(k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
                             table=table, q_start=aux[0], pages_per_step=aux[1])


jax.tree_util.register_pytree_node(PagedPrefillCache, _pprefill_flatten,
                                   _pprefill_unflatten)


# ---------------------------------------------------------------------------
# Prefix-sharing trie (one node per full page of prompt tokens)
# ---------------------------------------------------------------------------
class _PrefixNode:
    """Trie node: one physical page holding one page-sized token chunk."""
    __slots__ = ("slot", "children")

    def __init__(self, slot: int):
        self.slot = slot
        self.children: Dict[Tuple[int, ...], "_PrefixNode"] = {}


# ---------------------------------------------------------------------------
# Page pool (host-side allocator shared by all layers of a model)
# ---------------------------------------------------------------------------
class PagePool:
    """Fixed pool of KV pages + refcounted free-list allocation + per-seq
    block tables + a prefix-sharing trie.

    One logical page slot spans every layer (each layer keeps its own
    (P, KV, ps, hd) arrays; a sequence's block table indexes all of them),
    so allocation is a single free-list pop per ``page_size`` tokens.
    Admission control is conservative: :meth:`reserve` claims the worst-case
    page count for a sequence up front, so a running sequence can never
    deadlock the pool mid-decode.

    **Sharing.** Every slot carries a refcount (table references only — the
    trie holds no references of its own). :meth:`reserve` with a prompt
    first walks the trie (:meth:`match_prefix`) and shares the pages of the
    longest registered full-page prefix instead of allocating them;
    :meth:`fork` clones a whole sequence by increffing its table. Shared
    pages are immutable through any table: all writers must go through
    :meth:`ensure_writable`, which copies the page to a fresh slot on the
    first divergent write (COW) and drops stale trie entries.

    **Retention.** When a trie-indexed prefix page's last reference dies it
    is *retained* — parked in a bounded LRU (``retain_pages`` slots, default
    the whole pool) with its trie entry intact — instead of freed, so a
    re-submitted prompt re-shares the pages its predecessor wrote. Retained
    slots still count as reclaimable (:attr:`num_free` includes them):
    allocation evicts LRU-first under pool pressure, and eviction is what
    finally drops the trie entry. Slots with no trie entry free immediately,
    as before.

    **Mesh sharding.** With ``mesh=`` (and a ``model`` axis that divides
    ``n_kv_heads``), page and scale *storage* is laid out head-sharded over
    the model axis — each device holds ``n_kv_heads / model`` heads of every
    page — while all control state (free list, refcounts, block tables,
    trie) stays replicated host-side. Scales are per (page, head, token),
    so quantization during ingest/append/write_chunk is shard-local and the
    int8 pages are never gathered in HBM; the head-sharded shard_map
    attention kernels consume the storage exactly as laid out.

    **Rollback.** :meth:`truncate` rewinds a sequence to its first
    ``n`` tokens — the speculative-decoding engine calls it to discard a
    rejected draft suffix. Pages are write-once at token granularity, so
    the rewind is pure metadata: the kept prefix's bytes are untouched
    (bit-identical to never having written the suffix), stale rows past the
    new length are masked by every reader and overwritten by later appends.
    ``drop_unused_pages=True`` additionally trims the block table to the
    pages the new length needs, decreffing the rest (retention/trie rules
    as in :meth:`release`).
    """

    def __init__(self, *, n_layers: int, n_kv_heads: int, head_dim: int,
                 num_pages: int, page_size: int = DEFAULT_PAGE_SIZE,
                 quantized: bool = True, dtype=jnp.bfloat16,
                 mesh=None, retain_pages: Optional[int] = None):
        self.n_layers = n_layers
        self.n_kv_heads = n_kv_heads
        self.head_dim = head_dim
        self.num_pages = num_pages
        self.page_size = page_size
        self.quantized = quantized
        self.dtype = dtype
        self.mesh = None
        self._page_sharding = None
        self._scale_sharding = None
        if effective_model_shards(mesh, n_kv_heads) > 1:
            self.mesh = mesh
            self._page_sharding = NamedSharding(
                mesh, P(None, "model", None, None))
            self._scale_sharding = NamedSharding(mesh, P(None, "model", None))
        shape = (num_pages, n_kv_heads, page_size, head_dim)
        page_dtype = jnp.int8 if quantized else dtype

        def pages():
            return self._pin(jnp.zeros(shape, page_dtype),
                             self._page_sharding)

        def scales():
            return self._pin(jnp.full((num_pages, n_kv_heads, page_size),
                                      SCALE_EPS, jnp.float32),
                             self._scale_sharding)

        self.k_pages: List[jax.Array] = [pages() for _ in range(n_layers)]
        self.v_pages: List[jax.Array] = [pages() for _ in range(n_layers)]
        if quantized:
            self.k_scale: List[Optional[jax.Array]] = [
                scales() for _ in range(n_layers)]
            self.v_scale: List[Optional[jax.Array]] = [
                scales() for _ in range(n_layers)]
        else:
            self.k_scale = [None] * n_layers
            self.v_scale = [None] * n_layers
        self.free: List[int] = list(range(num_pages))
        self.ref: List[int] = [0] * num_pages
        self.tables: Dict[int, List[int]] = {}
        self.lens: Dict[int, int] = {}
        self.retain_pages = num_pages if retain_pages is None else retain_pages
        self._retained: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()          # LRU: oldest first
        self._prefix_root = _PrefixNode(-1)
        self._prefix_nodes: Dict[int, Tuple[_PrefixNode, Tuple[int, ...]]] = {}

    @staticmethod
    def _pin(x: Optional[jax.Array], sharding) -> Optional[jax.Array]:
        if x is None or sharding is None:
            return x
        return jax.device_put(x, sharding)

    @property
    def sharded(self) -> bool:
        """Page storage laid out head-sharded over a mesh's model axis?"""
        return self._page_sharding is not None

    # -- accounting ------------------------------------------------------
    @property
    def num_free(self) -> int:
        """Reclaimable slots: truly free plus retained (evictable) ones."""
        return len(self.free) + len(self._retained)

    @property
    def num_retained(self) -> int:
        return len(self._retained)

    def pages_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.page_size))

    def can_reserve(self, n_tokens: int, prompt=None) -> bool:
        """Would :meth:`reserve` succeed? (the one copy of the fit formula)

        Shared prefix pages that are currently *retained* (ref 0) are about
        to be revived out of the reclaimable set, so they can't double-count
        as both shared and free.
        """
        shared = self.match_prefix(prompt)[1] if prompt is not None else []
        revived = sum(1 for s in shared if self.ref[s] == 0)
        return (self.pages_for(n_tokens) - len(shared)
                <= self.num_free - revived)

    def page_bytes(self) -> int:
        """HBM bytes one page slot occupies across all layers (k + v)."""
        per = self.n_kv_heads * self.page_size * self.head_dim
        itemsize = 1 if self.quantized else jnp.dtype(self.dtype).itemsize
        scale = (2 * 4 * self.n_kv_heads * self.page_size
                 if self.quantized else 0)
        return self.n_layers * (2 * per * itemsize + scale)

    # -- prefix trie -----------------------------------------------------
    def match_prefix(self, tokens) -> Tuple[int, List[int]]:
        """Longest registered full-page prefix of ``tokens`` → (n, slots).

        Matching is capped at the last full page *strictly before* the final
        prompt token, so an admitted sequence always prefills at least one
        token (it needs logits at the last position to sample from).
        """
        ps = self.page_size
        limit = max(0, (len(tokens) - 1) // ps)
        node, slots = self._prefix_root, []
        for i in range(limit):
            nxt = node.children.get(tuple(tokens[i * ps:(i + 1) * ps]))
            if nxt is None:
                break
            slots.append(nxt.slot)
            node = nxt
        return len(slots) * ps, slots

    def register_prefix(self, seq_id: int, tokens) -> int:
        """Index a prefilled prompt's full pages for future sharing.

        Only the prompt's full pages are registered — they are immutable
        from here on (decode appends land at page ``len(prompt) // ps``,
        which is never one of them). Existing nodes win, so all sequences
        carrying a popular prefix converge on one physical page chain.
        Returns the number of pages newly indexed.
        """
        ps = self.page_size
        node, table, added = self._prefix_root, self.tables[seq_id], 0
        for i in range(len(tokens) // ps):
            chunk = tuple(tokens[i * ps:(i + 1) * ps])
            nxt = node.children.get(chunk)
            if nxt is None:
                slot = table[i]
                if slot in self._prefix_nodes:       # already indexed elsewhere
                    break
                nxt = _PrefixNode(slot)
                node.children[chunk] = nxt
                self._prefix_nodes[slot] = (node, chunk)
                added += 1
            node = nxt
        return added

    def _prefix_forget(self, slot: int) -> None:
        """Drop a slot's trie entry (it is being freed or rewritten)."""
        loc = self._prefix_nodes.pop(slot, None)
        if loc is None:
            return
        parent, key = loc
        node = parent.children.get(key)
        if node is not None and node.slot == slot:
            del parent.children[key]

    # -- alloc / free ----------------------------------------------------
    def _incref(self, slot: int) -> None:
        if self.ref[slot] <= 0:
            raise RuntimeError(f"incref of free page {slot}")
        self.ref[slot] += 1

    def _share(self, slot: int) -> None:
        """Take a reference on a trie-matched slot, reviving it out of the
        retained LRU if its last table reference already died."""
        if self.ref[slot] == 0:
            if slot not in self._retained:
                raise RuntimeError(f"sharing non-retained free page {slot}")
            del self._retained[slot]
            self.ref[slot] = 1
        else:
            self.ref[slot] += 1

    def _decref(self, slot: int) -> None:
        if self.ref[slot] <= 0:
            raise RuntimeError(f"double free of page {slot}")
        self.ref[slot] -= 1
        if self.ref[slot] == 0:
            if slot in self._prefix_nodes and self.retain_pages > 0:
                # park in the LRU with the trie entry intact: a re-submitted
                # prompt re-shares this page instead of re-prefilling it
                self._retained[slot] = None
                while len(self._retained) > self.retain_pages:
                    self._evict_retained()
            else:
                self._prefix_forget(slot)
                self.free.append(slot)

    def _evict_retained(self) -> None:
        """Evict the least-recently-retained prefix page to the free list."""
        slot, _ = self._retained.popitem(last=False)
        self._prefix_forget(slot)
        self.free.append(slot)

    def _alloc(self) -> int:
        if not self.free:
            self._evict_retained()     # LRU-first under pool pressure
        slot = self.free.pop()
        self.ref[slot] = 1
        return slot

    def reserve(self, seq_id: int, n_tokens: int, prompt=None) -> int:
        """Claim pages covering ``n_tokens`` worst-case for a new sequence.

        With ``prompt`` (a token sequence), the trie is consulted first and
        the matched prefix pages are *shared* (increffed — reviving retained
        pages) instead of allocated — only the non-shared remainder comes
        off the free list. Returns the number of prompt tokens already
        covered by shared pages (``lens[seq_id]`` starts there; the caller
        prefills the rest).
        """
        if seq_id in self.tables:
            raise ValueError(f"seq {seq_id} already resident")
        matched, shared = (0, [])
        if prompt is not None:
            matched, shared = self.match_prefix(prompt)
        # revive/incref the shared chain first so eviction can't claim it
        for slot in shared:
            self._share(slot)
        need = self.pages_for(n_tokens) - len(shared)
        if need > self.num_free:
            for slot in shared:
                self._decref(slot)     # rollback (back to retained/trie)
            raise RuntimeError(
                f"page pool exhausted: need {need}, free {self.num_free}")
        self.tables[seq_id] = shared + [self._alloc() for _ in range(need)]
        self.lens[seq_id] = matched
        return matched

    def release(self, seq_id: int) -> None:
        """Drop a finished/evicted sequence's page references; slots whose
        last reference dies return to the free list — except trie-indexed
        prefix pages, which park in the retained LRU for future sharing."""
        for slot in self.tables.pop(seq_id):
            self._decref(slot)
        self.lens.pop(seq_id)

    def fork(self, parent_id: int, child_id: int) -> None:
        """O(1) copy-on-write clone: the child shares every parent page.

        Physical copies happen lazily, page by page, when either sequence
        first writes a shared page (:meth:`ensure_writable`).
        """
        if child_id in self.tables:
            raise ValueError(f"seq {child_id} already resident")
        table = self.tables[parent_id]
        for slot in table:
            self._incref(slot)
        self.tables[child_id] = list(table)
        self.lens[child_id] = self.lens[parent_id]

    def truncate(self, seq_id: int, n_tokens: int, *,
                 drop_unused_pages: bool = False) -> None:
        """Token-granular rollback: rewind ``seq_id`` to its first
        ``n_tokens`` tokens.

        Write-once pages make this pure metadata — the kept prefix is
        bit-identical to a history in which the dropped suffix was never
        written; rows past the new length are masked by every reader and
        overwritten (token by token) by whatever comes next. The engine's
        speculative-decoding path calls this after verification to discard
        a rejected draft suffix while keeping the sequence's worst-case
        page reservation (the rewound positions will be rewritten).

        ``drop_unused_pages=True`` also trims the block table to the pages
        ``n_tokens`` needs and decrefs the dropped slots — shared slots
        survive under their other holders, trie-indexed slots whose last
        reference dies park in the retained LRU, the rest return to the
        free list (exactly :meth:`release` semantics, suffix-only). Note a
        rewind never forces COW by itself: a later write into a still-
        shared tail page crosses :meth:`ensure_writable` as usual.
        """
        if not 0 <= n_tokens <= self.lens[seq_id]:
            raise ValueError(
                f"truncate({seq_id}, {n_tokens}): cached {self.lens[seq_id]}")
        self.lens[seq_id] = n_tokens
        if drop_unused_pages:
            keep = self.pages_for(n_tokens)
            table = self.tables[seq_id]
            for slot in table[keep:]:
                self._decref(slot)
            del table[keep:]

    def ensure_writable(self, seq_id: int, page_idx: int) -> int:
        """COW barrier: make ``tables[seq_id][page_idx]`` exclusively owned.

        Exclusive already → just invalidate any trie entry (its content is
        about to change) and return the slot. Shared → copy the page (all
        layers, k+v+scales) to a fresh slot, swap it into this table only,
        and decref the original. Every write path must pass through here so
        a shared page is never mutated through any block table.
        """
        slot = self.tables[seq_id][page_idx]
        if self.ref[slot] == 1:
            self._prefix_forget(slot)
            return slot
        if not self.free and not self._retained:
            raise RuntimeError("page pool exhausted during copy-on-write")
        new = self._alloc()
        for arrs in (self.k_pages, self.v_pages, self.k_scale, self.v_scale):
            for layer in range(self.n_layers):
                if arrs[layer] is not None:
                    arrs[layer] = arrs[layer].at[new].set(arrs[layer][slot])
        self.ref[slot] -= 1                    # was > 1: never reaches zero
        self.tables[seq_id][page_idx] = new
        return new

    # -- diagnostics -----------------------------------------------------
    def shared_page_stats(self) -> Dict[str, int]:
        """Block-table occupancy: logical entries vs distinct physical slots."""
        entries = sum(len(t) for t in self.tables.values())
        counts: Dict[int, int] = {}
        for table in self.tables.values():
            for slot in table:
                counts[slot] = counts.get(slot, 0) + 1
        shared = sum(1 for c in counts.values() if c > 1)
        return {"table_entries": entries, "distinct_slots": len(counts),
                "shared_slots": shared}

    def check_invariants(self) -> None:
        """Allocator soundness (exercised by the property tests): no leaked
        or double-freed slots, refcounts equal table references, free slots
        unreferenced, retained slots unreferenced-but-indexed, trie entries
        alive or retained."""
        assert len(self.free) == len(set(self.free)), "duplicate free slots"
        counts: Dict[int, int] = {}
        for table in self.tables.values():
            for slot in table:
                counts[slot] = counts.get(slot, 0) + 1
        for slot in range(self.num_pages):
            assert self.ref[slot] == counts.get(slot, 0), (
                f"slot {slot}: ref {self.ref[slot]} != "
                f"{counts.get(slot, 0)} table refs")
        assert (len(self.free) + len(self._retained) + len(counts)
                == self.num_pages), "slot leak"
        assert len(self._retained) <= self.retain_pages or \
            self.retain_pages == 0, "retained LRU over capacity"
        for slot in self.free:
            assert self.ref[slot] == 0
            assert slot not in self._retained, f"slot {slot} free+retained"
        for slot in self._retained:
            assert self.ref[slot] == 0, f"retained slot {slot} referenced"
            assert slot in self._prefix_nodes, \
                f"retained slot {slot} not in trie"
        for slot in self._prefix_nodes:
            assert self.ref[slot] > 0 or slot in self._retained, \
                f"trie references free slot {slot}"

    # -- data movement ---------------------------------------------------
    def ingest(self, seq_id: int, layer: int, k_t: jax.Array,
               v_t: jax.Array, start: int = 0) -> None:
        """Quantize one layer's KV (1, KV, S, hd) into pages [start, start+S).

        ``start`` must be page-aligned (the engine's chunking guarantees it).
        The written pages must be exclusively owned — shared prefix pages are
        exactly the tokens the caller skips.
        """
        ps = self.page_size
        if start % ps:
            raise ValueError(f"ingest start {start} not page-aligned")
        s = k_t.shape[2]
        p0 = start // ps
        n_pages = self.pages_for(s)
        if p0 + n_pages > len(self.tables[seq_id]):
            raise RuntimeError(f"seq {seq_id}: prefill exceeds reservation")
        table = self.tables[seq_id][p0:p0 + n_pages]
        for slot in table:
            if self.ref[slot] > 1:
                raise RuntimeError(f"ingest would write shared page {slot}")
        slots = jnp.asarray(table, jnp.int32)
        for pages, scales, x in ((self.k_pages, self.k_scale, k_t),
                                 (self.v_pages, self.v_scale, v_t)):
            xp = _chunk_to_pages(x, n_pages, ps)
            if self.quantized:
                xq, sc = _quantize_page_block(xp)
                scales[layer] = scales[layer].at[slots].set(sc)
            else:
                xq = xp.astype(pages[layer].dtype)
            pages[layer] = pages[layer].at[slots].set(xq)
        self.lens[seq_id] = start + s

    def batch_tables(self, seq_ids) -> Tuple[jax.Array, jax.Array]:
        """Padded (B, max_pages) block table + (B,) lengths for a decode."""
        max_pages = max(len(self.tables[s]) for s in seq_ids)
        rows = [self.tables[s] + [0] * (max_pages - len(self.tables[s]))
                for s in seq_ids]
        return (jnp.asarray(rows, jnp.int32),
                jnp.asarray([self.lens[s] for s in seq_ids], jnp.int32))

    def layer_cache(self, layer: int, tables: jax.Array,
                    lengths: jax.Array) -> PagedDecodeCache:
        return PagedDecodeCache(
            k_pages=self.k_pages[layer], v_pages=self.v_pages[layer],
            k_scale=self.k_scale[layer], v_scale=self.v_scale[layer],
            tables=tables, lengths=lengths)

    def prefill_cache(self, layer: int, seq_id: int, q_start: int,
                      pages_per_step: int = 1) -> PagedPrefillCache:
        """One layer's view for one sequence's prefill chunk at ``q_start``."""
        return PagedPrefillCache(
            k_pages=self.k_pages[layer], v_pages=self.v_pages[layer],
            k_scale=self.k_scale[layer], v_scale=self.v_scale[layer],
            table=jnp.asarray(self.tables[seq_id], jnp.int32),
            q_start=q_start, pages_per_step=pages_per_step)

    def writeback(self, layer: int, cache) -> None:
        """Store a decode/prefill step's functional updates back into the
        pool (:class:`PagedDecodeCache` and :class:`PagedPrefillCache` share
        the page/scale field names). Sharded pools re-pin the arrays to the
        head-sharded layout in case an op's output sharding drifted."""
        self.k_pages[layer] = self._pin(cache.k_pages, self._page_sharding)
        self.v_pages[layer] = self._pin(cache.v_pages, self._page_sharding)
        self.k_scale[layer] = self._pin(cache.k_scale, self._scale_sharding)
        self.v_scale[layer] = self._pin(cache.v_scale, self._scale_sharding)
