"""Speculative decoding: draft–verify serving on the paged int8 KV cache.

Batch-1 decode is memory-bound — every step streams the whole quantized
weight set through the CAMP GEMMs to produce one token. Speculative
decoding turns that stream into a **multi-token verification panel**: a
drafter proposes up to γ cheap tokens, the target model scores all of them
in ONE forward over the paged cache (the γ+1-token query rides the chunked
paged-prefill kernel path, mid-page ``q_start`` and all), and an exact
acceptance–rejection step keeps the longest draft prefix the target agrees
with — emitting between 1 and γ+1 tokens per weight stream. The γ+1-row
GEMMs are exactly the small-but-dense quantized panels the paper's hybrid
multiplier targets; ``warm_gemm_autotune(spec_gammas=...)`` pre-tunes them.

Three layers:

* **drafters** — anything satisfying the :class:`Drafter` protocol.
  :class:`NGramDrafter` is model-free prompt-lookup (continue the most
  recent earlier occurrence of the trailing n-gram); its proposals are
  deterministic (one-hot draft distribution). :class:`DraftModelDrafter`
  runs a small causal LM (any all-attention ``ModelConfig``, e.g.
  qwen2-0.5b drafting for qwen2-72b) over its **own** paged int8 pool,
  lazily syncing to the verified history (truncate + catch-up feed) each
  step, so rejected drafts never pollute its cache.
* **verification** — :func:`accept_speculative` implements the exact
  acceptance–rejection rule: accept draft i with probability
  min(1, p_i(d_i)/q_i(d_i)); on the first rejection sample the residual
  norm(max(p−q, 0)); if everything is accepted sample one bonus token from
  the last row. Greedy sampling degenerates to "accept while the draft
  equals the target argmax" — the emitted stream is *identical* to
  non-speculative greedy decoding — and temperature sampling preserves the
  target distribution exactly (the classic speculative-sampling theorem).
* **rollback** — the engine writes draft KV into the sequence's pages
  *before* verification (that is what makes the panel one forward), then
  calls :meth:`PagePool.truncate` to discard the rejected suffix. Pages
  are write-once at token granularity, so the rollback leaves the kept
  prefix bit-identical to a run that never speculated.

The engine integration (scheduling, stats, γ autotune) lives in
:class:`repro.serving.engine.ContinuousBatchingEngine`; this module has no
engine import.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Configuration + stats
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SpecConfig:
    """How an engine should speculate.

    ``method``: 'off' | 'ngram' | 'draft'. ``gamma``: speculation window
    (draft tokens per step), or 'auto' to pick from the measured acceptance
    rate through the persistent autotune cache (``spec|`` keys).
    ``draft_cfg``/``draft_params``: the small draft LM for method='draft'.
    ``ngram_max``/``ngram_min``: prompt-lookup n-gram sizes tried, longest
    first.
    """
    method: str = "off"
    gamma: Any = 4                       # int or "auto"
    ngram_max: int = 3
    ngram_min: int = 1
    ngram_window: int = 4096             # trailing tokens scanned per lookup
    draft_cfg: Any = None                # ModelConfig
    draft_params: Any = None
    draft_page_size: Optional[int] = None
    draft_capacity_tokens: Optional[int] = None


@dataclasses.dataclass
class SpecStats:
    """Draft/verify accounting (per request and engine-aggregate)."""
    steps: int = 0                       # verification forwards run
    proposed: int = 0                    # draft tokens scored
    accepted: int = 0                    # draft tokens kept
    emitted: int = 0                     # tokens emitted by spec steps

    def add(self, proposed: int, accepted: int, emitted: int) -> None:
        self.steps += 1
        self.proposed += proposed
        self.accepted += accepted
        self.emitted += emitted

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    @property
    def mean_tokens_per_step(self) -> float:
        return self.emitted / self.steps if self.steps else 0.0

    def summary(self) -> Dict[str, float]:
        return {"spec_steps": self.steps, "proposed": self.proposed,
                "accepted": self.accepted, "emitted": self.emitted,
                "acceptance_rate": self.acceptance_rate,
                "mean_tokens_per_step": self.mean_tokens_per_step}


# ---------------------------------------------------------------------------
# One sequence's multi-token chunk through the paged-prefill call path
# ---------------------------------------------------------------------------
def paged_chunk_forward(params, cfg, pool, seq_id: int, tokens, start: int, *,
                        pages_per_step: int = 1, logits: str = "all"):
    """Drive ``forward()`` over one sequence's chunk via PagedPrefillCache
    views: write the chunk's KV into the pool's pages, attend over the
    whole cached prefix, store the functional updates back, advance
    ``pool.lens``. The one implementation behind the engine's prefill
    lane, its speculative verify panels, and the draft model's
    catch-up/propose steps. ``logits``: 'all' (1, C, V) | 'last' (1, 1, V)
    | 'none' (skip the vocabulary head). ``start`` need not be
    page-aligned (write-once token rows — see :mod:`~repro.serving.kv_cache`).
    """
    from repro.models.transformer import forward  # lazy: avoids an import
    # cycle through models.attention when repro.serving initializes
    toks = jnp.asarray(tokens, jnp.int32).reshape(1, -1)
    c = toks.shape[1]
    positions = (start + jnp.arange(c))[None]
    caches = [{"attn": pool.prefill_cache(i, seq_id, start, pages_per_step)}
              for i in range(cfg.n_layers)]
    kw = {"last_logits_only": True} if logits == "last" else \
        {"return_hidden": True} if logits == "none" else {}
    out, new_caches, _ = forward(params, cfg, toks, positions=positions,
                                 caches=caches, **kw)
    for i, layer in enumerate(new_caches):
        pool.writeback(i, layer["attn"])
    pool.lens[seq_id] = start + int(c)
    return None if logits == "none" else out


# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------
class Drafter(Protocol):
    """Proposes up to ``gamma`` continuation tokens for one sequence.

    ``propose`` returns (tokens, q) where ``q`` is a (len(tokens), V)
    f32 array of the draft distribution each token was sampled from, or
    None for a deterministic drafter (one-hot q — acceptance then tests
    the raw target probability of the proposed token).
    ``cost_ratio`` is the drafter's rough per-token cost relative to one
    target decode step (feeds the γ autotune). ``release`` drops any
    per-sequence state when the engine retires the request.
    """
    cost_ratio: float

    def propose(self, seq_id: int, history: Sequence[int], gamma: int, *,
                reserve_tokens: int = 0
                ) -> Tuple[List[int], Optional[np.ndarray]]: ...

    def release(self, seq_id: int) -> None: ...


class NGramDrafter:
    """Model-free prompt-lookup drafting.

    Finds the most recent earlier occurrence of the history's trailing
    n-gram (n from ``max_n`` down to ``min_n``) and proposes the tokens
    that followed it. Free to run, deterministic, and very effective on
    repetitive contexts (code, retrieved documents, generation loops).
    ``scan_window`` bounds the host-side lookup to the trailing W tokens
    of the history so a 32k context doesn't pay an O(L) python scan per
    decode step (matches crop with full positions, so proposals are
    identical whenever the match lies inside the window).
    """

    cost_ratio = 0.0

    def __init__(self, max_n: int = 3, min_n: int = 1,
                 scan_window: int = 4096):
        if min_n < 1 or max_n < min_n:
            raise ValueError(f"bad n-gram range [{min_n}, {max_n}]")
        self.max_n, self.min_n = max_n, min_n
        self.scan_window = scan_window

    def propose(self, seq_id: int, history: Sequence[int], gamma: int, *,
                reserve_tokens: int = 0):
        h = list(history)[-self.scan_window:]
        for n in range(self.max_n, self.min_n - 1, -1):
            if len(h) <= n:
                continue
            pat = h[-n:]
            # most recent earlier occurrence with a full-γ continuation
            # wins; matches flush against the tail only yield their short
            # suffix, so fall back to the longest continuation seen
            # (i + n <= len(h) - 1, so a continuation is never empty)
            best: List[int] = []
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == pat:
                    cont = h[i + n:i + n + gamma]
                    if len(cont) == gamma:
                        return cont, None
                    if len(cont) > len(best):
                        best = cont
            if best:
                return best, None
        return [], None

    def release(self, seq_id: int) -> None:
        pass


class DraftModelDrafter:
    """A small causal LM drafting over its own paged int8 pool.

    The draft cache is kept consistent with the *verified* history lazily:
    at each ``propose`` the longest common prefix of the cached tokens and
    the current history survives (:meth:`PagePool.truncate` rewinds past
    it — rejected drafts from the previous step fall off here), and the
    unseen suffix is fed as one catch-up chunk through the same
    paged-prefill path the target's verifier uses. Then γ single-token
    steps autoregress the proposals, recording the full draft distribution
    per token so acceptance–rejection can be exact under temperature
    sampling.
    """

    cost_ratio = 0.25

    def __init__(self, params, cfg, *, sample: str = "greedy",
                 temperature: float = 1.0, key: Optional[jax.Array] = None,
                 page_size: Optional[int] = None,
                 capacity_tokens: Optional[int] = None,
                 pages_per_step: int = 2):
        from repro.serving import kv_cache as kvc
        mixers = {cfg.mixer_of(i) for i in range(cfg.n_layers)}
        if mixers != {"attn"}:
            raise ValueError(
                f"draft model needs attention mixers, got {mixers}")
        self.params, self.cfg = params, cfg
        self.sample, self.temperature = sample, temperature
        self.key = jax.random.PRNGKey(1) if key is None else key
        self.pages_per_step = pages_per_step
        ps = page_size or kvc.DEFAULT_PAGE_SIZE
        capacity = capacity_tokens or 8 * cfg.max_seq_len
        self.pool = kvc.PagePool(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, num_pages=-(-capacity // ps), page_size=ps,
            quantized=True, dtype=jnp.dtype(cfg.dtype))
        self.cached: Dict[int, List[int]] = {}   # tokens whose KV is cached

    def _forward_chunk(self, seq_id: int, tokens: List[int],
                       start: int) -> np.ndarray:
        """Feed ``tokens`` at positions [start, start+m); last-row logits."""
        need = self.pool.pages_for(start + len(tokens))
        if need > len(self.pool.tables[seq_id]):
            raise RuntimeError(
                f"draft seq {seq_id}: {start + len(tokens)} tokens exceed "
                f"the {len(self.pool.tables[seq_id])}-page reservation")
        logits = paged_chunk_forward(
            self.params, self.cfg, self.pool, seq_id, tokens, start,
            pages_per_step=self.pages_per_step, logits="last")
        return np.asarray(logits[0, -1], np.float32)

    def propose(self, seq_id: int, history: Sequence[int], gamma: int, *,
                reserve_tokens: int = 0):
        history = list(history)
        if seq_id not in self.pool.tables:
            need = max(reserve_tokens, len(history) + 1)
            if not self.pool.can_reserve(need):
                # the draft pool is its own admission domain: when it can't
                # hold this sequence, decline to draft (the engine falls
                # back to plain decode) instead of aborting the serve loop —
                # space frees up as other sequences finish and release()
                return [], None
            self.pool.reserve(seq_id, need)
            self.cached[seq_id] = []
        cached = self.cached[seq_id]
        # survive on the longest verified prefix; rewind the rest
        n = 0
        for a, b in zip(cached, history):
            if a != b:
                break
            n += 1
        if n < len(cached):
            self.pool.truncate(seq_id, n)
            del cached[n:]
        feed = history[n:]               # ≥ 1: history grew since last step
        tokens: List[int] = []
        qs: List[np.ndarray] = []
        for _ in range(gamma):
            logits = self._forward_chunk(seq_id, feed, len(cached))
            cached.extend(feed)
            if self.sample == "greedy":
                t = int(logits.argmax())
                qs.append(None)
            else:
                p = _softmax(logits / self.temperature)
                k = jax.random.fold_in(
                    jax.random.fold_in(self.key, seq_id), len(cached))
                t = int(jax.random.categorical(k, jnp.asarray(np.log(
                    np.maximum(p, 1e-30)))))
                qs.append(p)
            tokens.append(t)
            feed = [t]
        if self.sample == "greedy" or not tokens:
            return tokens, None
        return tokens, np.stack(qs)

    def release(self, seq_id: int) -> None:
        if seq_id in self.pool.tables:
            self.pool.release(seq_id)
        self.cached.pop(seq_id, None)


def make_drafter(spec: SpecConfig, *, sample: str = "greedy",
                 temperature: float = 1.0,
                 key: Optional[jax.Array] = None) -> Drafter:
    if spec.method == "ngram":
        return NGramDrafter(max_n=spec.ngram_max, min_n=spec.ngram_min,
                            scan_window=spec.ngram_window)
    if spec.method == "draft":
        if spec.draft_cfg is None or spec.draft_params is None:
            raise ValueError("method='draft' needs draft_cfg + draft_params")
        return DraftModelDrafter(
            spec.draft_params, spec.draft_cfg, sample=sample,
            temperature=temperature, key=key,
            page_size=spec.draft_page_size,
            capacity_tokens=spec.draft_capacity_tokens)
    raise ValueError(f"unknown spec method {spec.method!r}")


# ---------------------------------------------------------------------------
# Exact acceptance–rejection
# ---------------------------------------------------------------------------
def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def accept_speculative(rows: np.ndarray, draft: Sequence[int],
                       draft_q: Optional[np.ndarray], *, sample: str,
                       temperature: float, key: jax.Array, seq_id: int,
                       start_index: int) -> Tuple[int, List[int]]:
    """Exact draft verification. Returns (n_accepted, emitted_tokens).

    ``rows``: (len(draft)+1, V) f32 target logits — row i scores the token
    after position i of the panel [last_sampled, d_1, …, d_γ]. ``draft_q``:
    (len(draft), V) draft distributions, or None for a deterministic
    drafter (one-hot). ``start_index``: how many tokens the request had
    emitted before this step — randomness is folded per
    (seq_id, emitted-token index), so an emitted position draws the same
    stream no matter how many drafts preceded it.

    * greedy — accept while the draft matches the target argmax; the first
      mismatch emits the target argmax instead; full acceptance emits the
      bonus argmax of the last row. The emitted stream is exactly
      non-speculative greedy decoding.
    * temperature — accept d_i w.p. min(1, p_i(d_i)/q_i(d_i)); on first
      rejection sample the residual norm(max(p−q, 0)); on full acceptance
      sample the bonus row. Each emitted token is marginally distributed as
      softmax(row/T) — the target distribution — for any draft proposal.
    """
    emitted: List[int] = []
    if sample == "greedy":
        for i, d in enumerate(draft):
            t = int(rows[i].argmax())
            if t != int(d):
                emitted.append(t)
                return i, emitted
            emitted.append(t)
        emitted.append(int(rows[len(draft)].argmax()))
        return len(draft), emitted

    def pos_key(i: int) -> jax.Array:
        return jax.random.fold_in(jax.random.fold_in(key, seq_id),
                                  start_index + i)

    for i, d in enumerate(draft):
        d = int(d)
        p = _softmax(rows[i] / temperature)
        if draft_q is None:
            q_d = 1.0                    # deterministic drafter: one-hot q
            q = np.zeros_like(p)
            q[d] = 1.0
        else:
            q = draft_q[i]
            q_d = float(q[d])
        u = float(jax.random.uniform(jax.random.fold_in(pos_key(i), 0)))
        if q_d > 0 and u < float(p[d]) / q_d:
            emitted.append(d)
            continue
        residual = np.maximum(p - q, 0.0)
        z = residual.sum()
        r = residual / z if z > 0 else p     # q ⊇ p: degenerate, resample p
        t = int(jax.random.categorical(
            jax.random.fold_in(pos_key(i), 1),
            jnp.asarray(np.log(np.maximum(r, 1e-30)))))
        emitted.append(t)
        return i, emitted
    g = len(draft)
    p = _softmax(rows[g] / temperature)
    t = int(jax.random.categorical(
        jax.random.fold_in(pos_key(g), 1),
        jnp.asarray(np.log(np.maximum(p, 1e-30)))))
    emitted.append(t)
    return g, emitted
