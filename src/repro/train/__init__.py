from repro.train.train_step import build_train_step, init_train_state
