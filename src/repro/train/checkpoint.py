"""Sharded checkpointing with elastic (mesh-changing) restore.

Design (orbax-free, offline container):

* ``save(dir, state, step)`` — flattens the state pytree (QuantizedTensor and
  optimizer-moment nodes included) to path-keyed arrays, writes one ``.npz``
  plus a JSON manifest, atomically (tmp dir + rename). Optionally async
  (background thread) so the training loop never blocks on I/O.
* ``restore(dir, like, mesh_shardings)`` — loads the newest step and
  ``device_put``s each leaf with the *target* sharding. Because leaves are
  stored unsharded, restoring onto a different mesh shape (elastic scaling:
  save on (2,2), restore on (4,2)) is just a different ``device_put`` —
  tested in tests/test_checkpoint.py.
* crash safety — a checkpoint directory is only visible under its final name;
  ``find_latest`` ignores half-written tmp dirs, so restart-from-latest after
  a kill is always consistent.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

_CKPT_RE = re.compile(r"^step_(\d+)$")


def _flatten(state) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "fiub":      # ml_dtypes (bf16, …) → f32
            arr = arr.astype(np.float32)      # lossless widening for bf16
        flat[key] = arr
    return flat


def save(ckpt_dir, state, step: int, *, keep: int = 3,
         async_: bool = False) -> Optional[threading.Thread]:
    """Write checkpoint ``step_<step>`` under ``ckpt_dir``."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)   # snapshot on caller thread (values are immutable)

    def _write():
        tmp = ckpt_dir / f".tmp_step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "keys": sorted(flat)}))
        final = ckpt_dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def all_steps(ckpt_dir) -> list:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        m = _CKPT_RE.match(p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def find_latest(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a state pytree or shape tree).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put with them (elastic restore onto any mesh).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = find_latest(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    data = np.load(ckpt_dir / f"step_{step}" / "arrays.npz")

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), shard in zip(paths, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = data[key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        out = jax.numpy.asarray(arr).astype(want_dtype)  # jnp handles bf16
        if shard is not None:
            out = jax.device_put(out, shard)
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)
