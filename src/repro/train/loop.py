"""Fault-tolerant training loop.

Features (per the large-scale-runnability brief):

* **checkpoint/restart** — periodic async sharded checkpoints; on start the
  loop restores the newest consistent checkpoint and replays the data stream
  from that step (pipeline is step-addressable, so restart is exact).
* **preemption safety** — SIGTERM/SIGINT trigger a synchronous checkpoint
  before exit (cluster preemption contract).
* **straggler monitor** — per-step wall-time EWMA; steps slower than
  ``straggler_factor ×`` EWMA are logged with their step index. On a real
  multi-pod job this signal feeds the scheduler (slice hot-swap / re-shard);
  here it is surfaced in metrics and tested by injecting an artificial stall.
* **metrics** — loss/grad-norm/step-time history returned to the caller.
"""
from __future__ import annotations

import signal
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib


class StragglerMonitor:
    """Per-step wall-time EWMA with deadline flagging.

    The first ``warmup`` observations are excluded from the estimate — step 0
    includes jit compilation and would otherwise poison the EWMA for dozens
    of steps (real clusters exclude warmup for the same reason).
    """

    def __init__(self, factor: float = 3.0, ewma: float = 0.9,
                 warmup: int = 2):
        self.factor = factor
        self.ewma_coef = ewma
        self.warmup = warmup
        self.seen = 0
        self.ewma: Optional[float] = None
        self.events: list = []

    def observe(self, step: int, dt: float) -> bool:
        self.seen += 1
        if self.seen <= self.warmup:
            return False
        is_straggler = (self.ewma is not None
                        and dt > self.factor * self.ewma
                        and self.ewma > 0)
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
        # stragglers don't poison the estimate
        if self.ewma is None:
            self.ewma = dt
        elif not is_straggler:
            self.ewma = self.ewma_coef * self.ewma + (1 - self.ewma_coef) * dt
        return is_straggler


def run(train_step: Callable, state: Any, data, *, steps: int,
        ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
        log_every: int = 10, straggler_factor: float = 3.0,
        on_metrics: Optional[Callable[[int, dict], None]] = None):
    """Run up to ``steps`` total steps, resuming from the latest checkpoint.

    ``data``: object with ``batch_at(step) -> dict`` (step-addressable).
    Returns (state, history dict).
    """
    start_step = 0
    if ckpt_dir is not None:
        latest = ckpt_lib.find_latest(ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore(ckpt_dir, state, step=latest)
            start_step = latest
            print(f"[loop] restored checkpoint step {latest}")

    monitor = StragglerMonitor(factor=straggler_factor)
    history = {"loss": [], "step_time": [], "straggler_steps": []}
    stop = {"now": False}

    def _sig(_s, _f):
        stop["now"] = True
    old_handlers = {s: signal.signal(s, _sig)
                    for s in (signal.SIGTERM, signal.SIGINT)}
    pending_save = None
    try:
        step_fn = jax.jit(train_step, donate_argnums=0)
        for step in range(start_step, steps):
            t0 = time.time()
            batch = data.batch_at(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if monitor.observe(step, dt):
                history["straggler_steps"].append(step)
                print(f"[loop] straggler at step {step}: {dt:.2f}s "
                      f"(ewma {monitor.ewma:.2f}s)")
            history["loss"].append(loss)
            history["step_time"].append(dt)
            if on_metrics:
                on_metrics(step, {"loss": loss, "dt": dt})
            if log_every and step % log_every == 0:
                print(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                pending_save = ckpt_lib.save(ckpt_dir, state, step + 1,
                                             async_=True)
            if stop["now"]:
                print(f"[loop] signal received — checkpointing at step {step + 1}")
                break
    finally:
        for s, h in old_handlers.items():
            signal.signal(s, h)
    if pending_save is not None:
        pending_save.join()
    if ckpt_dir and stop["now"]:
        ckpt_lib.save(ckpt_dir, state, step + 1)
    history["monitor"] = monitor.events
    return state, history
