"""Train-step builder: loss → grads → (optional compression) → AdamW.

* Gradient accumulation is a **python-unrolled** microbatch loop (dry-run
  FLOP-accounting rule; see DESIGN.md) — at 256 chips the production configs
  fit full-batch, so accumulation is a runtime feature, not a dry-run one.
* ``compress_grads='int8'`` applies CAMP-style int8 quantize→dequantize to
  gradients *before* the (GSPMD-inserted) data-parallel all-reduce psums.
  Under automatic partitioning XLA reduces in the quantized values' dtype
  domain (f32 payload, int8 information content); the bandwidth claim is made
  precise in the manual shard_map collective (repro.parallel.collectives),
  this flag reproduces the numerics.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import loss_fn
from repro.optim.adamw import Optimizer


def init_train_state(key, cfg: ModelConfig, optimizer: Optimizer):
    from repro.models.transformer import init_params
    params = init_params(key, cfg)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _int8_compress(g: jax.Array) -> jax.Array:
    """Quantize→dequantize a gradient leaf (per-last-dim-row absmax int8)."""
    if g.ndim == 0:
        return g
    g32 = g.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(g32), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127)
    return (q * scale).astype(g.dtype)


def build_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                     grad_accum: int = 1,
                     compress_grads: Optional[str] = None,
                     loss: Callable = loss_fn):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``batch['inputs']``: (GB, S) (or (GB, S, D) for frontend-stub archs),
    ``batch['labels']``: (GB, S). With ``grad_accum=k`` the leading dim is
    split into k python-unrolled microbatches.
    """

    def one_microbatch(params, mb):
        return jax.value_and_grad(lambda p: loss(p, cfg, mb))(params)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            lval, grads = one_microbatch(params, batch)
        else:
            gb = batch["labels"].shape[0]
            assert gb % grad_accum == 0, (gb, grad_accum)
            mbs = gb // grad_accum
            lval = jnp.zeros((), jnp.float32)
            grads = None
            for i in range(grad_accum):          # unrolled (see module doc)
                mb = {k: v[i * mbs:(i + 1) * mbs] for k, v in batch.items()}
                lv, g = one_microbatch(params, mb)
                lval = lval + lv / grad_accum
                g = jax.tree.map(lambda x: x / grad_accum, g)
                grads = g if grads is None else jax.tree.map(
                    jnp.add, grads, g)

        if compress_grads == "int8":
            grads = jax.tree.map(_int8_compress, grads)

        updates, opt_state = optimizer.update(grads, state["opt"], params)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = {"loss": lval,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree.leaves(grads)))}
        return ({"params": new_params, "opt": opt_state,
                 "step": state["step"] + 1}, metrics)

    return train_step
