import os
import sys

# Tests see exactly ONE device (the dry-run's 512-device override lives only
# inside launch/dryrun.py, never globally).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
