"""Per-architecture smoke tests: reduced same-family config, one forward and
one train step on CPU, asserting output shapes and finite values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import forward, init_caches, init_params, loss_fn

B, S = 2, 32


def _inputs(cfg, key, s=S):
    if cfg.embedding_inputs:
        return jax.random.normal(key, (B, s, cfg.d_model), jnp.bfloat16)
    return jax.random.randint(key, (B, s), 0, cfg.vocab_size)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_params(key, cfg)
    x = _inputs(cfg, key)
    logits, _, _ = forward(params, cfg, x)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_finite(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_params(key, cfg)
    batch = {
        "inputs": _inputs(cfg, key),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch)))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g, np.float32)).all()
                          for g in leaves)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_then_decode(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_params(key, cfg)
    x = _inputs(cfg, key)
    caches = init_caches(cfg, B, S + 8)
    logits, caches, _ = forward(params, cfg, x, caches=caches)
    assert logits.shape == (B, S, cfg.vocab_size)
    step = _inputs(cfg, key, s=1)
    logits2, caches, _ = forward(params, cfg, step, caches=caches,
                                 cache_pos=jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_full_forward(arch, key):
    """Incremental decode must agree with a full forward over the same tokens."""
    cfg = get_config(arch, reduced=True)
    if cfg.embedding_inputs:
        pytest.skip("frontend-stub archs exercise token path via labels only")
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, 12), 0, cfg.vocab_size)
    full_logits, _, _ = forward(params, cfg, toks)

    caches = init_caches(cfg, B, 16)
    _, caches, _ = forward(params, cfg, toks[:, :8], caches=caches)
    logits_inc = []
    for t in range(8, 12):
        lg, caches, _ = forward(params, cfg, toks[:, t:t + 1], caches=caches,
                                cache_pos=jnp.int32(t))
        logits_inc.append(lg)
    inc = jnp.concatenate(logits_inc, axis=1)
    np.testing.assert_allclose(
        np.asarray(inc, np.float32), np.asarray(full_logits[:, 8:12], np.float32),
        rtol=0.15, atol=0.15)  # bf16 forward; recurrent state in f32
