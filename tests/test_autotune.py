"""Autotuned block selection + blocking arithmetic."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.blocking import MXU, VMEM_BYTES, BlockConfig, choose_blocks


def test_vmem_bytes_is_plain_bits_to_bytes():
    b = BlockConfig(bm=128, bn=256, bk=512)
    # 8-bit A and B: 1 byte/element, double-buffered; +int32 acc +f32 out.
    assert b.vmem_bytes(8, 8) == 2 * (128 * 512 + 512 * 256) + 2 * 4 * 128 * 256
    # 4-bit weights halve the B stream.
    assert b.vmem_bytes(4, 8) == 2 * (128 * 512 + 512 * 256 // 2) + 2 * 4 * 128 * 256
    # 4-bit activations halve the A stream.
    assert b.vmem_bytes(8, 4) == 2 * (128 * 512 // 2 + 512 * 256) + 2 * 4 * 128 * 256
    assert b.vmem_bytes(4, 4) == 2 * ((128 * 512 + 512 * 256) // 2) + 2 * 4 * 128 * 256


def test_candidates_fit_budget_and_include_seed():
    for kind in autotune.KINDS:
        for (m, n, k) in [(4096, 4096, 4096), (16, 8192, 8192), (129, 333, 130)]:
            cands = autotune.candidates(kind, m, n, k)
            assert cands, (kind, m, n, k)
            seed = choose_blocks(m, n, k)
            for (bm, bn, bk) in cands:
                assert bm <= m and bn <= n and bk <= k
                if kind != "i8":
                    assert bk % 2 == 0
            # the analytic seed (possibly evened) is always explored
            assert any(bm == seed.bm and bn == seed.bn for (bm, bn, bk) in cands)


def test_model_time_monotone_in_work():
    small = autotune.model_time_s("i8", 128, 128, 128, (128, 128, 128))
    big = autotune.model_time_s("i8", 4096, 4096, 4096, (256, 256, 512))
    assert big > small


def test_fused_model_removes_activation_restream():
    # Prefill-shaped GEMM, many j-columns: unfused re-reads the int8 A per
    # column block; fused streams the A row panel once. The model must see it.
    m, n, k = 512, 8192, 4096
    blk = (256, 256, 512)
    t_fused = autotune.model_time_s("i8", m, n, k, blk, fused=True, a_in_bytes=2)
    t_unfused = autotune.model_time_s("i8", m, n, k, blk, fused=False)
    assert t_fused < t_unfused


def test_get_blocks_caches_and_persists(tmp_path, monkeypatch):
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    autotune.clear_cache()
    blk = autotune.get_blocks("i8", 512, 512, 512)
    assert os.path.exists(cache)
    data = json.load(open(cache))
    assert len(data) == 1
    (entry,) = data.values()
    assert tuple(entry["block"]) == blk
    assert entry["source"] == "model"  # CPU backend → analytic fallback
    # warm in-memory hit and cold-process disk hit both return the same block
    assert autotune.get_blocks("i8", 512, 512, 512) == blk
    autotune.clear_cache()
    assert autotune.get_blocks("i8", 512, 512, 512) == blk
    autotune.clear_cache()


def test_tune_with_custom_timer_picks_argmin(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    autotune.clear_cache()
    cands = autotune.candidates("i8", 1024, 1024, 1024)
    want = cands[-1]
    blk = autotune.tune("i8", 1024, 1024, 1024,
                        timer=lambda b: 0.0 if b == want else 1.0)
    assert blk == want
    assert autotune.get_blocks("i8", 1024, 1024, 1024) == want
    autotune.clear_cache()


def test_get_page_size_caches_and_respects_timer(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    autotune.clear_cache()
    ps = autotune.get_page_size(8, 128, 2048)
    assert ps in autotune.PAGE_SIZES
    data = json.load(open(tmp_path / "c.json"))
    assert any(k.startswith("pattn|") for k in data)
    # cached: a contradictory timer must NOT override the stored pick
    assert autotune.get_page_size(8, 128, 2048,
                                  timer=lambda p: -p) == ps
    # fresh shape with a timer favoring the largest page
    assert autotune.get_page_size(8, 128, 4096, timer=lambda p: -p) == \
        max(autotune.PAGE_SIZES)
    autotune.clear_cache()


def test_warm_gemm_autotune_covers_moe_expert_shapes(tmp_path, monkeypatch):
    from repro.configs import get_config
    from repro.serving.engine import warm_gemm_autotune
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    autotune.clear_cache()
    cfg = get_config("moonshot-v1-16b-a3b", reduced=True, qmode="w8a8")
    assert cfg.moe_experts
    tuned = warm_gemm_autotune(cfg, batch_sizes=(1,))
    kns = {(k, n) for ((m, n, k), _) in tuned}
    assert (cfg.d_model, cfg.expert_ff) in kns    # expert up/gate
    assert (cfg.expert_ff, cfg.d_model) in kns    # expert down
    autotune.clear_cache()


def test_gemm_autotuned_default_blocks_run(tmp_path, monkeypatch):
    """ops.gemm_* with block=None (the default) must pick blocks that run —
    including shapes that are not multiples of anything in particular."""
    from repro.kernels import ops, ref
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    autotune.clear_cache()
    rng = np.random.default_rng(5)
    m, k, n = 130, 260, 70
    a = jnp.asarray(rng.integers(-127, 128, (m, k)).astype(np.int8))
    b = jnp.asarray(rng.integers(-127, 128, (k, n)).astype(np.int8))
    sa = jnp.asarray(rng.uniform(0.005, 0.02, (m, 1)).astype(np.float32))
    sb = jnp.asarray(rng.uniform(0.005, 0.02, (1, n)).astype(np.float32))
    got = ops.gemm_i8(a, b, sa, sb, impl="pallas")  # block=None → autotune
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.gemm_i8_ref(a, b, sa, sb)))
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    y = ops.gemm_i8_fused(x, b, sb, impl="pallas")
    assert y.shape == (m, n)
    autotune.clear_cache()
