"""Checkpoint/restore: roundtrip, crash consistency, elastic resharding."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _state(key):
    return {
        "params": {"w": jax.random.normal(key, (8, 16)),
                   "layers": [{"a": jnp.ones((4,))}, {"a": jnp.zeros((4,))}]},
        "opt": {"m": {"w": jnp.full((8, 16), 0.5)}, "count": jnp.int32(7)},
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path):
    state = _state(jax.random.PRNGKey(0))
    ckpt.save(tmp_path, state, 7)
    back = ckpt.restore(tmp_path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_and_latest(tmp_path):
    state = _state(jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, state, s, keep=2)
    assert ckpt.all_steps(tmp_path) == [3, 4]
    assert ckpt.find_latest(tmp_path) == 4


def test_async_save(tmp_path):
    state = _state(jax.random.PRNGKey(1))
    t = ckpt.save(tmp_path, state, 5, async_=True)
    t.join()
    assert ckpt.find_latest(tmp_path) == 5
    back = ckpt.restore(tmp_path, state)
    np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_halfwritten_checkpoint_ignored(tmp_path):
    state = _state(jax.random.PRNGKey(0))
    ckpt.save(tmp_path, state, 3)
    # simulate a crash mid-write: tmp dir left behind, no manifest
    (tmp_path / ".tmp_step_9").mkdir()
    (tmp_path / "step_9").mkdir()          # dir without manifest = torn write
    assert ckpt.find_latest(tmp_path) == 3


def test_quantized_tensor_leaves_roundtrip(tmp_path):
    from repro.core.quant import QuantizedTensor, quantize_weight
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    state = {"qw": quantize_weight(w, 8), "x": jnp.ones((3,))}
    ckpt.save(tmp_path, state, 1)
    back = ckpt.restore(tmp_path, state)
    assert isinstance(back["qw"], QuantizedTensor)
    np.testing.assert_array_equal(np.asarray(back["qw"].q),
                                  np.asarray(state["qw"].q))
    assert back["qw"].bits == 8


_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    from repro.train import checkpoint as ckpt

    state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
    mesh_a = make_test_mesh((2, 2))
    sharded = jax.device_put(state["w"], NamedSharding(mesh_a, P("data", "model")))
    ckpt.save({out!r}, {{"w": sharded}}, 1)

    # elastic: restore onto a DIFFERENT mesh shape (4x2)
    mesh_b = make_test_mesh((4, 2))
    tgt = NamedSharding(mesh_b, P("data", "model"))
    back = ckpt.restore({out!r}, {{"w": sharded}}, shardings={{"w": tgt}})
    assert back["w"].sharding == tgt, back["w"].sharding
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(state["w"]))
    print("ELASTIC_OK")
""")


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save on a (2,2) mesh, restore onto (4,2) — in a subprocess so the
    8-device override never leaks into this test session."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _ELASTIC.format(src=os.path.abspath(src), out=str(tmp_path))
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in res.stdout, res.stderr[-2000:]
