"""shard_map collective primitives, validated on an 8-virtual-device mesh in
a subprocess (device-count override must not leak into the session)."""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.collectives import (int8_allreduce_mean,
                                            ring_collective_matmul)

    mesh = make_test_mesh((2, 4))
    rng = np.random.default_rng(0)

    # ring collective matmul == plain matmul
    x = jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
    y = ring_collective_matmul(x, w, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-5)
    print("RING_OK")

    # int8 all-reduce mean ≈ exact mean within one quantization step
    g = jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32))
    got = int8_allreduce_mean(g, mesh, axis="data")
    step = float(jnp.max(jnp.abs(g))) / 127.0
    # every shard holds the same g here → mean == g
    assert np.abs(np.asarray(got) - np.asarray(g)).max() <= step
    print("AR_OK")
""")


def test_collectives_subprocess():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(src=os.path.abspath(src))
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=560)
    assert "RING_OK" in res.stdout and "AR_OK" in res.stdout, \
        (res.stdout[-500:], res.stderr[-3000:])
