"""Distribution-layer tests.

Sharding-rule resolution is tested in-process (pure logic); SPMD numerics
(sharded == single-device results) run in a subprocess with 8 virtual devices
so the device-count override never leaks into the test session.
"""
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import make_rules, spec_for


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = _FakeMesh({"data": 16, "model": 16})
MESH3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisible_dims_bind():
    rules = make_rules("train", family="dense")
    spec = spec_for((256, 4096), ("batch", "seq_act"), rules, MESH)
    assert spec == P(("data", "model"), None)


def test_indivisible_dims_fall_back_to_replicated():
    rules = make_rules("prefill", family="dense")
    # 14 heads don't divide model=16 → replicated
    spec = spec_for((32, 128, 14, 64), ("batch", "seq", "heads", "head_dim"),
                    rules, MESH)
    assert spec == P("data", None, None, None)
    # but 32 heads do
    spec = spec_for((32, 128, 32, 64), ("batch", "seq", "heads", "head_dim"),
                    rules, MESH)
    assert spec == P("data", None, "model", None)
    # batch smaller than the axis cannot shard at all
    spec = spec_for((2, 128, 32, 64), ("batch", "seq", "heads", "head_dim"),
                    rules, MESH)
    assert spec == P(None, None, "model", None)


def test_axis_used_once_per_tensor():
    rules = make_rules("decode", family="dense")
    # kv=32 grabs model; seq_kv then can't reuse it
    spec = spec_for((128, 32, 32768, 64),
                    ("batch", "kv_heads", "seq_kv", None), rules, MESH)
    assert spec == P("data", "model", None, None)
    # kv=8 can't bind → seq_kv takes model
    spec = spec_for((128, 8, 32768, 64),
                    ("batch", "kv_heads", "seq_kv", None), rules, MESH)
    assert spec == P("data", None, "model", None)


def test_greedy_prefix_joint_binding():
    rules = make_rules("train", multi_pod=True, family="dense")
    # multi-pod batch 256 over (data,model)=256 ✓
    spec = spec_for((256, 64), ("batch", None), rules, MESH3)
    assert spec == P(("data", "model"), None)
    # gb=8: data=16 doesn't divide → unsharded
    spec = spec_for((8, 64), ("batch", None), rules, MESH3)
    assert spec == P(None, None)


def test_params_pspecs_quantized_tensor():
    import jax
    import jax.numpy as jnp
    from repro.core.quant import QuantizedTensor
    from repro.parallel.sharding import params_pspecs

    tree = {"layers": [{"mlp": {"w_gate": jax.ShapeDtypeStruct((256, 512),
                                                               jnp.bfloat16)}}],
            "lm_head": QuantizedTensor(
                q=jax.ShapeDtypeStruct((256, 1024), jnp.int8),
                scale=jax.ShapeDtypeStruct((1, 1024), jnp.float32),
                bits=8, shape=(256, 1024))}
    rules = make_rules("train", family="dense")

    class M:
        shape = {"data": 16, "model": 16}
    specs = params_pspecs(tree, rules, M())
    assert specs["layers"][0]["mlp"]["w_gate"] == P("data", "model")
    assert specs["lm_head"].q == P("data", "model")
    assert specs["lm_head"].scale == P(None, "model")


_SPMD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import init_params, loss_fn
    from repro.parallel.sharding import make_rules, mesh_context, params_pspecs
    from repro.optim import adamw
    from repro.train import build_train_step, init_train_state

    cfg = get_config("qwen3-0.6b", reduced=True)
    opt = adamw(lr=1e-2)
    step = build_train_step(cfg, opt)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    data = {{
        "inputs": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                     cfg.vocab_size),
    }}
    # single-device reference
    ref_state, ref_metrics = jax.jit(step)(state, data)
    ref_loss = float(ref_metrics["loss"])

    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((4, 2))
    rules = make_rules("train", family="dense")
    state2 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    with mesh_context(mesh, rules):
        p_specs = params_pspecs(state2["params"], rules, mesh)
        sharded_params = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            state2["params"], p_specs,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
        state2 = {{**state2, "params": sharded_params}}
        sh_state, sh_metrics = jax.jit(step)(state2, data)
    sh_loss = float(sh_metrics["loss"])
    assert abs(ref_loss - sh_loss) < 5e-2, (ref_loss, sh_loss)
    a = np.asarray(ref_state["params"]["final_norm"], np.float32)
    b = np.asarray(sh_state["params"]["final_norm"], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-3)
    print("SPMD_OK", ref_loss, sh_loss)
""")


def test_sharded_train_step_matches_single_device():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SPMD.format(src=os.path.abspath(src))
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=560)
    assert "SPMD_OK" in res.stdout, (res.stdout[-1000:], res.stderr[-3000:])
