"""Fused quantize-in-kernel GEMM family vs the ref-oracle composition.

The contract under test (ISSUE 1 acceptance):

* fused w8a8 is **bit-identical** to the unfused
  ``quantize_rowwise`` → ``camp_gemm_i8`` pallas composition on
  block-divisible shapes (same for w4a8 / w4a4 against their compositions),
* fused epilogue math matches ``ref`` + XLA epilogue to f32 tolerance,
* non-block-divisible (M, N, K) go through the padded edge-block path and
  still match the oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import ops, ref
from repro.kernels.camp_gemm import camp_gemm_i8
from repro.kernels.camp_gemm_fused import (camp_gemm_fused_w4a4,
                                           camp_gemm_fused_w4a8,
                                           camp_gemm_fused_w8a8)
from repro.kernels.camp_gemm_w4 import camp_gemm_a4w4, camp_gemm_w4
from repro.kernels.epilogue import apply_epilogue, parse_epilogue
from repro.kernels.quantize import quantize_rowwise_kernel

RNG = np.random.default_rng(123)

# (M, K, N): one divisible, one fully non-divisible, one tiny decode row.
SHAPES = [(64, 128, 64), (50, 200, 72), (3, 96, 40)]
EPILOGUES = ["none", "bias", "silu", "gelu", "bias+silu", "residual", "mul",
             "bias+gelu+residual"]
QMODES = ["w8a8", "w4a8", "w4a4"]
BLOCK = (32, 32, 64)


def _fused_fn(qmode):
    return {"w8a8": camp_gemm_fused_w8a8, "w4a8": camp_gemm_fused_w4a8,
            "w4a4": camp_gemm_fused_w4a4}[qmode]


def _oracle(qmode, x, wq, stages, bias, operand):
    """ref quantize + ref GEMM + XLA epilogue, all in f32."""
    a_bits = 4 if qmode == "w4a4" else 8
    a_q, a_s = ref.quantize_rowwise_ref(x, a_bits)
    if qmode == "w8a8":
        y = ref.gemm_i8_ref(a_q, wq.q, a_s, wq.scale)
    else:
        y = ref.gemm_w4_ref(a_q, wq.q, a_s, wq.scale)
    return apply_epilogue(np.asarray(y), stages,
                          bias=None if bias is None else np.asarray(bias)[None],
                          operand=None if operand is None else np.asarray(operand))


@pytest.mark.parametrize("qmode", QMODES)
@pytest.mark.parametrize("epilogue", EPILOGUES)
@pytest.mark.parametrize("shape", SHAPES)
def test_fused_matches_ref_oracle(qmode, epilogue, shape):
    m, k, n = shape
    x = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((k, n)).astype(np.float32))
    wq = quant.quantize_weight(w, 4 if qmode.startswith("w4") else 8)
    stages = parse_epilogue(epilogue)
    bias = operand = None
    if "bias" in stages:
        bias = jnp.asarray(RNG.standard_normal(n).astype(np.float32))
    if "residual" in stages or "mul" in stages:
        operand = jnp.asarray(RNG.standard_normal((m, n)).astype(np.float32))
    want = _oracle(qmode, x, wq, stages, bias, operand)
    bm, bn, bk = BLOCK
    got = _fused_fn(qmode)(x, wq.q, wq.scale, block_m=bm, block_n=bn,
                           block_k=bk, epilogue=epilogue, bias=bias,
                           operand=operand, interpret=True)
    # f32 tolerance: the jitted kernel's scale division can differ from the
    # eager oracle's by 1 ULP (documented in test_kernels.py); the int math
    # itself is exact.
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("qmode", QMODES)
def test_fused_bit_identical_to_unfused_composition(qmode):
    """On divisible shapes the fused kernel must reproduce the two-kernel
    pallas composition bit for bit — the in-VMEM quantize is the same f32
    expression chain as the standalone quantize kernel."""
    m, k, n = 128, 256, 128
    x = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((k, n)).astype(np.float32))
    bm, bn, bk = 64, 64, 64
    if qmode == "w8a8":
        wq = quant.quantize_weight(w, 8)
        a_q, a_s = quantize_rowwise_kernel(x, bits=8, block_m=bm, interpret=True)
        want = camp_gemm_i8(a_q, wq.q, a_s, wq.scale, block_m=bm, block_n=bn,
                            block_k=bk, interpret=True)
    elif qmode == "w4a8":
        wq = quant.quantize_weight(w, 4)
        a_q, a_s = quantize_rowwise_kernel(x, bits=8, block_m=bm, interpret=True)
        want = camp_gemm_w4(a_q, wq.q, a_s, wq.scale, block_m=bm, block_n=bn,
                            block_k=bk, interpret=True)
    else:
        wq = quant.quantize_weight(w, 4)
        a_q, a_s = quantize_rowwise_kernel(x, bits=4, block_m=bm, interpret=True)
        a_packed = quant.pack_int4(a_q.T).T
        want = camp_gemm_a4w4(a_packed, wq.q, a_s, wq.scale, block_m=bm,
                              block_n=bn, block_k=bk, interpret=True)
    got = _fused_fn(qmode)(x, wq.q, wq.scale, block_m=bm, block_n=bn,
                           block_k=bk, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_bf16_activations():
    m, k, n = 48, 192, 64
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.bfloat16)
    wq = quant.quantize_weight(
        jnp.asarray(RNG.standard_normal((k, n)).astype(np.float32)), 8)
    # Bit-identical to the jitted two-kernel composition (both sides compute
    # the scale with the same jitted division)...
    a_q, a_s = quantize_rowwise_kernel(x, bits=8, block_m=16, interpret=True)
    want = camp_gemm_i8(a_q, wq.q, a_s, wq.scale, block_m=16, block_n=32,
                        block_k=64, interpret=True)
    got = camp_gemm_fused_w8a8(x, wq.q, wq.scale, block_m=16, block_n=32,
                               block_k=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # ...and within quantization-flip distance of the eager ref composition:
    # a 1-ULP scale difference can flip a borderline rounding, and one flipped
    # int8 in row i moves every output of that row by |b_kj|·sa·sb ≤ 127·sa·sb.
    a_r, s_r = ref.quantize_rowwise_ref(x, 8)
    ref_out = np.asarray(ref.gemm_i8_ref(a_r, wq.q, s_r, wq.scale))
    step = np.asarray(s_r) * np.asarray(wq.scale)
    assert (np.abs(np.asarray(got) - ref_out) <= 2 * 127 * step + 1e-5).all()


@pytest.mark.parametrize("shape", [(100, 200, 72), (60, 100, 40), (7, 30, 130)])
def test_unfused_kernels_padded_edge_blocks(shape):
    """The unfused kernels accept arbitrary (M, N, K) via zero padding."""
    m, k, n = shape
    a = jnp.asarray(RNG.integers(-127, 128, (m, k)).astype(np.int8))
    sa = jnp.asarray(RNG.uniform(0.005, 0.02, (m, 1)).astype(np.float32))
    sb = jnp.asarray(RNG.uniform(0.005, 0.02, (1, n)).astype(np.float32))
    b = jnp.asarray(RNG.integers(-127, 128, (k, n)).astype(np.int8))
    got = camp_gemm_i8(a, b, sa, sb, block_m=64, block_n=64, block_k=64,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.gemm_i8_ref(a, b, sa, sb)))
    b4 = jnp.asarray(RNG.integers(-7, 8, (k, n)).astype(np.int8))
    bp = quant.pack_int4(b4)
    got = camp_gemm_w4(a, bp, sa, sb, block_m=64, block_n=64, block_k=64,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.gemm_w4_ref(a, bp, sa, sb)))
    a4 = RNG.integers(-7, 8, (m, k)).astype(np.int8)
    ap = quant.pack_int4(jnp.asarray(a4).T).T
    got = camp_gemm_a4w4(ap, bp, sa, sb, block_m=64, block_n=64, block_k=64,
                         interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.gemm_a4w4_ref(ap, bp, k, sa, sb)))


def test_quantize_kernel_padded_rows():
    x = jnp.asarray(RNG.standard_normal((100, 48)).astype(np.float32))
    q, s = ops.quantize_rowwise(x, impl="pallas", block_m=64)
    q_r, s_r = ref.quantize_rowwise_ref(x, 8)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), rtol=2e-7)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_ops_fused_dispatch_all_kinds(impl):
    rng = np.random.default_rng(7)  # fixed data: tolerances are per-dataset
    m, k, n = 32, 128, 48
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    exact = np.asarray(x @ w)
    tol = {"w8a8": 0.02, "w4a8": 0.2, "w4a4": 0.35}
    fns = {"w8a8": ops.gemm_i8_fused, "w4a8": ops.gemm_w4_fused,
           "w4a4": ops.gemm_a4w4_fused}
    for qmode, fn in fns.items():
        wq = quant.quantize_weight(w, 4 if qmode.startswith("w4") else 8)
        y = np.asarray(fn(x, wq.q, wq.scale, impl=impl,
                          block=(16, 16, 64) if impl == "pallas" else None))
        err = np.abs(y - exact).max() / np.abs(exact).max()
        assert err < tol[qmode], (qmode, impl, err)


def test_epilogue_parse_validation():
    assert parse_epilogue(None) == ()
    assert parse_epilogue("none") == ()
    assert parse_epilogue("bias+silu") == ("bias", "silu")
    with pytest.raises(ValueError):
        parse_epilogue("bias+swish")
    with pytest.raises(ValueError):
        parse_epilogue("residual+mul")  # two operand stages
    with pytest.raises(ValueError):
        parse_epilogue("bias+bias")
    with pytest.raises(ValueError):
        # stages demand tensors the caller didn't pass
        camp_gemm_i8(jnp.zeros((8, 8), jnp.int8), jnp.zeros((8, 8), jnp.int8),
                     jnp.ones((8, 1)), jnp.ones((1, 8)), epilogue="bias",
                     interpret=True)


@pytest.mark.parametrize("impl", ["xla", "ref", "hybrid", "pallas"])
def test_ops_reject_orphan_bias_on_every_impl(impl):
    """bias= without epilogue='bias' must raise on ALL impls, not just pallas
    (a silently dropped bias on the CPU fallback would only crash on TPU)."""
    a = jnp.zeros((8, 8), jnp.int8)
    b = jnp.zeros((8, 8), jnp.int8)
    sa, sb = jnp.ones((8, 1)), jnp.ones((1, 8))
    with pytest.raises(ValueError):
        ops.gemm_i8(a, b, sa, sb, impl=impl, bias=jnp.ones(8),
                    block=(8, 8, 8))
    with pytest.raises(ValueError):
        ops.gemm_i8(a, b, sa, sb, impl=impl, epilogue="mul",
                    block=(8, 8, 8))  # operand stage without operand


def test_fused_hybrid_impl_is_exact_and_actually_hybrid():
    """impl='hybrid' on the fused path must run the §3 decomposition (exact
    vs the int32 dot) rather than silently falling back to plain XLA."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    for qmode, fn in (("w8a8", ops.gemm_i8_fused), ("w4a8", ops.gemm_w4_fused)):
        wq = quant.quantize_weight(w, 4 if qmode == "w4a8" else 8)
        got = fn(x, wq.q, wq.scale, impl="hybrid")
        want = fn(x, wq.q, wq.scale, impl="xla")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_linear_preserves_weight_only_qmode_on_bit_mismatch():
    """A weight-only request must never be downgraded to an activation-
    quantized integer mode just because the stored weight bits differ."""
    from repro.core import camp
    from repro.models.modules import linear
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    wq4 = camp.prepare_weight(w, "w4a16")
    got = linear(x, wq4, qmode="w8a16")  # wrong weight bits, still 'a16'
    want = camp.camp_matmul(x, wq4, qmode="w4a16")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
