"""Per-kernel validation: shape/dtype sweeps, every impl vs the jnp oracle.

Integer paths must be bit-exact across impls (same quantized inputs); float
epilogues compare with tight allclose (1-ULP scale differences between eager
and jitted division are expected).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import camp, hybrid, quant
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)

SHAPES = [(8, 16, 8), (128, 128, 128), (256, 512, 384), (64, 1024, 128),
          (512, 256, 512)]
BLOCKS = [(64, 64, 64), (128, 128, 128), (128, 128, 256)]


def _qdata(m, k, n):
    a = RNG.integers(-127, 128, (m, k)).astype(np.int8)
    b = RNG.integers(-127, 128, (k, n)).astype(np.int8)
    sa = RNG.uniform(0.005, 0.02, (m, 1)).astype(np.float32)
    sb = RNG.uniform(0.005, 0.02, (1, n)).astype(np.float32)
    return map(jnp.asarray, (a, b, sa, sb))


@pytest.mark.parametrize("shape", SHAPES)
def test_gemm_i8_impls_agree(shape):
    m, k, n = shape
    a, b, sa, sb = _qdata(m, k, n)
    want = np.asarray(ref.gemm_i8_ref(a, b, sa, sb))
    for impl in ("xla", "hybrid"):
        got = np.asarray(ops.gemm_i8(a, b, sa, sb, impl=impl))
        np.testing.assert_array_equal(got, want, err_msg=impl)
    got = np.asarray(ops.gemm_i8(a, b, sa, sb, impl="pallas",
                                 block=(64, 64, 64)))
    np.testing.assert_array_equal(got, want, err_msg="pallas")


@pytest.mark.parametrize("block", BLOCKS)
def test_gemm_i8_pallas_blocks(block):
    m, k, n = 256, 512, 256
    a, b, sa, sb = _qdata(m, k, n)
    want = np.asarray(ref.gemm_i8_ref(a, b, sa, sb))
    got = np.asarray(ops.gemm_i8(a, b, sa, sb, impl="pallas", block=block))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_i8_out_dtypes(out_dtype):
    a, b, sa, sb = _qdata(128, 256, 128)
    want = np.asarray(ref.gemm_i8_ref(a, b, sa, sb, out_dtype), np.float32)
    got = np.asarray(ops.gemm_i8(a, b, sa, sb, impl="pallas",
                                 block=(64, 64, 64), out_dtype=out_dtype),
                     np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-2)


@pytest.mark.parametrize("shape", SHAPES)
def test_gemm_w4_impls_agree(shape):
    m, k, n = shape
    a = jnp.asarray(RNG.integers(-127, 128, (m, k)).astype(np.int8))
    b4 = jnp.asarray(RNG.integers(-7, 8, (k, n)).astype(np.int8))
    bp = quant.pack_int4(b4)
    sa = jnp.asarray(RNG.uniform(0.005, 0.02, (m, 1)).astype(np.float32))
    sb = jnp.asarray(RNG.uniform(0.005, 0.02, (1, n)).astype(np.float32))
    want = np.asarray(ref.gemm_w4_ref(a, bp, sa, sb))
    for impl in ("xla", "hybrid"):
        np.testing.assert_array_equal(
            np.asarray(ops.gemm_w4(a, bp, sa, sb, impl=impl)), want,
            err_msg=impl)
    got = np.asarray(ops.gemm_w4(a, bp, sa, sb, impl="pallas",
                                 block=(64, 64, 64)))
    np.testing.assert_array_equal(got, want)


def test_gemm_a4w4_pallas():
    m, k, n = 128, 256, 128
    a4 = RNG.integers(-7, 8, (m, k)).astype(np.int8)
    b4 = RNG.integers(-7, 8, (k, n)).astype(np.int8)
    ap = quant.pack_int4(jnp.asarray(a4).T).T
    bp = quant.pack_int4(jnp.asarray(b4))
    sa = jnp.asarray(RNG.uniform(0.005, 0.02, (m, 1)).astype(np.float32))
    sb = jnp.asarray(RNG.uniform(0.005, 0.02, (1, n)).astype(np.float32))
    want = np.asarray(ref.gemm_a4w4_ref(ap, bp, k, sa, sb))
    got = np.asarray(ops.gemm_a4w4(ap, bp, k, sa, sb, impl="pallas",
                                   block=(64, 64, 64)))
    np.testing.assert_array_equal(got, want)
    # and exact vs direct int matmul
    direct = (a4.astype(np.int32) @ b4.astype(np.int32)).astype(np.float32)
    np.testing.assert_allclose(want, direct * np.asarray(sa) * np.asarray(sb),
                               rtol=1e-6)


@pytest.mark.parametrize("mk", [(8, 32), (256, 512), (64, 8192)])
@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_kernel_matches_ref(mk, bits):
    m, k = mk
    x = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    q_p, s_p = ops.quantize_rowwise(x, bits=bits, impl="pallas",
                                    block_m=min(64, m))
    q_r, s_r = ref.quantize_rowwise_ref(x, bits)
    np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=2e-7)


def test_hybrid_exhaustive_scalar_square():
    """The paper's §3 identity, exhaustively over all int8×int8 pairs."""
    a = np.arange(-128, 128, dtype=np.int8).reshape(-1, 1)
    b = np.arange(-128, 128, dtype=np.int8).reshape(1, -1)
    got = np.asarray(hybrid.hybrid_matmul_i8(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, a.astype(np.int32) @ b.astype(np.int32))


def test_hybrid_w4a8_exhaustive():
    a = np.arange(-128, 128, dtype=np.int8).reshape(-1, 1)
    b = np.arange(-8, 8, dtype=np.int8).reshape(1, -1)
    got = np.asarray(hybrid.hybrid_matmul_w4a8(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, a.astype(np.int32) @ b.astype(np.int32))


def test_camp_matmul_all_qmodes_close_to_fp32():
    x = jnp.asarray(RNG.standard_normal((64, 256)).astype(np.float32))
    w = jnp.asarray(RNG.standard_normal((256, 128)).astype(np.float32))
    exact = np.asarray(x @ w)
    scale = np.abs(exact).max()
    tol = {"w8a8": 0.02, "w8a16": 0.02, "w4a8": 0.15, "w4a16": 0.15,
           "w4a4": 0.25}
    for qmode, t in tol.items():
        wq = camp.prepare_weight(w, qmode)
        y = np.asarray(camp.camp_matmul(x, wq, qmode=qmode))
        err = np.abs(y - exact).max() / scale
        assert err < t, (qmode, err)


def test_blocking_fits_vmem_and_divides():
    from repro.core.blocking import choose_blocks, VMEM_BYTES
    for (m, n, k) in [(4096, 8192, 8192), (512, 512, 512), (128, 384, 640),
                      (1024, 152064, 8192)]:
        b = choose_blocks(m, n, k)
        assert m % b.bm == 0 and n % b.bn == 0 and k % b.bk == 0
        assert b.vmem_bytes() <= VMEM_BYTES // 2


@pytest.mark.parametrize("shape", [(1, 32, 8), (4, 64, 16), (2, 128, 32)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("blocks", [(16, 16), (32, 16), (64, 64)])
def test_flash_attention_vs_oracle(shape, causal, blocks):
    from repro.kernels.flash_attention import flash_attention
    bh, s, d = shape
    bq, bk = blocks
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, bh, s, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, bh, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, bh, s, d)).astype(np.float32))
    want = ref.attention_ref(q, k, v, causal=causal)[0]
    got = flash_attention(q[0], k[0], v[0], causal=causal, block_q=bq,
                          block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((2, 64, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 64, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 64, 16)), jnp.bfloat16)
    want = ref.attention_ref(q[None], k[None], v[None], causal=True)[0]
    got = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2,
                               atol=5e-2)
