"""Paged/quantized KV cache: int8 round-trip accuracy, paged-vs-dense
content equivalence, page eviction/refill, stale-page masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kv_cache import (DenseKVCache, PagePool, int8_scale,
                                    quantize_int8)

KV, HD, PS = 2, 16, 8


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def test_dense_int8_roundtrip_prefill_and_append():
    b, s, t = 2, 11, 24
    cache = DenseKVCache.init(b, KV, t, HD, jnp.float32, quantized=True,
                              page_size=PS)
    k = _rand(0, b, KV, s, HD)
    v = _rand(1, b, KV, s, HD)
    cache = cache.write_prefill(k, v)
    appended = [_rand(10 + i, b, KV, 1, HD) for i in range(3)]
    for i in range(3):   # crosses the s=11 → page-1/page-2 boundary
        cache = cache.append(appended[i], _rand(20 + i, b, KV, 1, HD),
                             jnp.int32(s + i))
    k_all, v_all = cache.read(jnp.float32)          # (B, T, KV, hd)
    got = np.asarray(jnp.swapaxes(k_all, 1, 2))[:, :, :s]
    # per-page scale: a page's step is its amax/127; appends requantize the
    # page they land in (the appended token may raise its amax), and each
    # requantize adds up to half a step — bound by 1.6 steps of the global
    # amax including the appended tokens
    amax = max(float(jnp.max(jnp.abs(k))),
               max(float(jnp.max(jnp.abs(a))) for a in appended))
    assert np.abs(got - np.asarray(k)).max() <= 1.6 * amax / 127
    assert np.isfinite(np.asarray(v_all)).all()


def test_dense_append_matches_prefill_content():
    """Appending tokens one-by-one must equal prefilling them in bulk."""
    b, s = 1, PS + 3
    k = _rand(2, b, KV, s, HD)
    v = _rand(3, b, KV, s, HD)
    bulk = DenseKVCache.init(b, KV, s, HD, jnp.float32, quantized=True,
                             page_size=PS).write_prefill(k, v)
    inc = DenseKVCache.init(b, KV, s, HD, jnp.float32, quantized=True,
                            page_size=PS)
    for i in range(s):
        inc = inc.append(k[:, :, i:i + 1], v[:, :, i:i + 1], jnp.int32(i))
    kb, vb = bulk.read(jnp.float32)
    ki, vi = inc.read(jnp.float32)
    # bulk quantizes each token once (error ≤ 0.5 step); incremental
    # requantizes the page on every append (error accumulates to ~1 step) —
    # the two agree within 1.6 steps of the page amax
    tol = 1.6 * float(jnp.max(jnp.abs(jnp.stack([k, v])))) / 127
    np.testing.assert_allclose(np.asarray(kb)[:, :s], np.asarray(ki)[:, :s],
                               atol=tol)
    np.testing.assert_allclose(np.asarray(vb)[:, :s], np.asarray(vi)[:, :s],
                               atol=tol)


def _pool(num_pages=8, n_layers=1):
    return PagePool(n_layers=n_layers, n_kv_heads=KV, head_dim=HD,
                    num_pages=num_pages, page_size=PS, quantized=True)


def test_paged_ingest_is_exact_per_token_quantization():
    """Pool ingest stores exactly quantize(token, amax(token)/127) per
    (page, head, token) row — a pure function of each token's own values —
    and tracks the dense per-page slab within one quantization step."""
    s = 2 * PS + 5
    k = _rand(4, 1, KV, s, HD)
    v = _rand(5, 1, KV, s, HD)
    pool = _pool()
    pool.reserve(0, s)
    pool.ingest(0, 0, k, v)
    tables, lengths = pool.batch_tables([0])
    gathered = jnp.take(pool.k_pages[0], tables[0], axis=0)   # (np,KV,ps,hd)
    sc = jnp.take(pool.k_scale[0], tables[0], axis=0)         # (np,KV,ps)
    k_paged = (gathered.astype(jnp.float32) * sc[..., None])
    k_paged = jnp.swapaxes(k_paged, 0, 1).reshape(1, KV, -1, HD)
    k_paged = jnp.swapaxes(k_paged, 1, 2)           # (1, T, KV, hd)
    # exact write-once reference: each token quantized alone
    want_sc = int8_scale(k, axes=(3,))                        # (1, KV, s)
    want = quantize_int8(k, want_sc[..., None]).astype(jnp.float32) \
        * want_sc[..., None]
    np.testing.assert_array_equal(
        np.asarray(jnp.swapaxes(want, 1, 2))[0],
        np.asarray(k_paged)[0, :s])
    assert int(lengths[0]) == s
    # and the dense per-page slab agrees within its own (coarser) step
    dense = DenseKVCache.init(1, KV, s, HD, jnp.float32, quantized=True,
                              page_size=PS).write_prefill(k, v)
    k_dense, _ = dense.read(jnp.float32)            # (1, T, KV, hd)
    tol = 1.1 * float(jnp.max(jnp.abs(k))) / 127
    np.testing.assert_allclose(np.asarray(k_dense)[0, :s],
                               np.asarray(k_paged)[0, :s], atol=tol)


def test_pool_eviction_and_refill():
    """Released pages return to the free list and are safely reused."""
    pool = _pool(num_pages=4)
    pool.reserve(0, 4 * PS)                          # takes the whole pool
    assert pool.num_free == 0
    with pytest.raises(RuntimeError):
        pool.reserve(1, PS)
    big = 100.0 * jnp.ones((1, KV, 4 * PS, HD), jnp.float32)
    pool.ingest(0, 0, big, big)                      # dirty every page
    pool.release(0)
    assert pool.num_free == 4
    # refill with a small sequence on the dirty pages
    s = PS + 2
    k = _rand(6, 1, KV, s, HD)
    v = _rand(7, 1, KV, s, HD)
    pool.reserve(1, s + PS)
    pool.ingest(1, 0, k, v)
    tables, lengths = pool.batch_tables([1])
    cache = pool.layer_cache(0, tables, lengths)
    # append onto the partially-filled page: stale occupant values must not
    # leak into the content or inflate the fresh page scale
    knew = _rand(8, 1, KV, HD)
    cache = cache.append(knew, knew)
    slot = int(tables[0, s // PS])
    page = np.asarray(cache.k_pages[slot], np.float32) * \
        np.asarray(cache.k_scale[slot])[:, :, None]
    off = s % PS
    expect = np.asarray(k)[0, :, PS:s]               # page-1 prefix
    assert np.abs(page[:, :off] - expect).max() < 2e-2
    assert np.abs(page[:, off] - np.asarray(knew)[0]).max() < 2e-2
    assert np.abs(page[:, off + 1:]).max() == 0.0    # stale tail zeroed
    # scale reflects this page's content, not the evicted occupant's 100s
    amax = max(np.abs(expect).max(), np.abs(np.asarray(knew)).max())
    assert np.asarray(cache.k_scale[slot]).max() <= amax / 127 * 1.01


def test_append_across_page_boundary_allocated_pages():
    """Sequences own disjoint pages; batched append never collides."""
    pool = _pool(num_pages=8)
    for sid, s in ((0, PS - 1), (1, PS + 1)):        # straddle a boundary
        k = _rand(30 + sid, 1, KV, s, HD)
        pool.reserve(sid, s + 4)
        pool.ingest(sid, 0, k, k)
    tables, lengths = pool.batch_tables([0, 1])
    cache = pool.layer_cache(0, tables, lengths)
    for i in range(3):                               # seq 0 crosses into page 1
        knew = _rand(40 + i, 2, KV, HD)
        cache = cache.append(knew, knew)
    assert np.asarray(cache.lengths).tolist() == [PS + 2, PS + 4]
    own0 = set(pool.tables[0])
    own1 = set(pool.tables[1])
    assert not own0 & own1


def test_int8_helpers_round_trip():
    x = _rand(9, 4, 33)
    sc = int8_scale(x, axes=(1,))[:, None]
    q = quantize_int8(x, sc)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(sc) - np.asarray(x))
    assert err.max() <= 0.51 * np.asarray(sc).max()
