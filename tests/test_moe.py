"""MoE dispatch correctness: dropless equivalence against a direct top-k
mixture oracle, capacity-drop semantics, group-size invariance, and the
quantized (CAMP) expert path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_ffn, quantize_expert_weight


def _cfg(**kw):
    base = dict(name="m", family="moe", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=4, d_ff=64, vocab_size=256, moe_experts=4,
                moe_top_k=2, moe_d_ff=48, moe_capacity_factor=2.0)
    base.update(kw)
    return ModelConfig(**base)


def _dropless_oracle(p, cfg, x):
    t = x.reshape(-1, cfg.d_model)
    gates = jax.nn.softmax(t @ p["router"], -1)
    topv, topi = jax.lax.top_k(gates, cfg.moe_top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.moe_experts):
        h = jax.nn.silu(t @ p["experts"]["w_gate"][e]) * (t @ p["experts"]["w_up"][e])
        outs.append(h @ p["experts"]["w_down"][e])
    outs = jnp.stack(outs, 1)
    y = jnp.zeros_like(t)
    for j in range(cfg.moe_top_k):
        sel = jnp.take_along_axis(
            outs, topi[:, j][:, None, None].repeat(cfg.d_model, -1), 1)[:, 0]
        y += topv[:, j:j + 1] * sel
    return y.reshape(x.shape)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return cfg, p, x


def test_dropless_matches_oracle(setup):
    cfg, p, x = setup
    y, _ = moe_ffn(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_dropless_oracle(p, cfg, x)),
                               rtol=1e-5, atol=1e-6)


def test_capacity_drops_reduce_output_norm(setup):
    cfg, p, x = setup
    y_free, _ = moe_ffn(p, cfg, x)
    tight = dataclasses.replace(cfg, moe_capacity_factor=0.25)
    y_tight, _ = moe_ffn(p, tight, x)
    # dropped tokens lose expert contributions → strictly less output energy
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_free))


def test_group_size_invariance(setup):
    cfg, p, x = setup
    y1, _ = moe_ffn(p, cfg, x)
    old = moe_mod.MOE_GROUP_SIZE
    try:
        moe_mod.MOE_GROUP_SIZE = 8   # many small groups
        y2, _ = moe_ffn(p, cfg, x)
    finally:
        moe_mod.MOE_GROUP_SIZE = old
    # dropless: routing is per-token, groups only change dispatch layout
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("bits,tol", [(8, 0.03), (4, 0.25)])
def test_quantized_experts_close(setup, bits, tol):
    cfg, p, x = setup
    pq = dict(p)
    pq["experts"] = {k: quantize_expert_weight(v, bits)
                     for k, v in p["experts"].items()}
    y, _ = moe_ffn(p, cfg, x)
    yq, _ = moe_ffn(pq, cfg, x, qmode="w8a8" if bits == 8 else "w4a8")
    rel = float(jnp.abs(yq - y).max() / (jnp.abs(y).max() + 1e-9))
    assert rel < tol, rel


def test_grads_flow_through_dispatch(setup):
    cfg, p, x = setup
    g = jax.grad(lambda pp: moe_ffn(pp, cfg, x)[0].sum())(p)
    norms = [float(jnp.linalg.norm(l)) for l in jax.tree.leaves(g)]
    assert all(np.isfinite(norms))
    assert any(n > 0 for n in norms)


def test_aux_loss_uniformity(setup):
    """Perfectly uniform router → aux == 1 (its minimum for top-1 fractions)."""
    cfg, p, x = setup
    p2 = dict(p)
    p2["router"] = jnp.zeros_like(p["router"])
    _, aux = moe_ffn(p2, cfg, x)
    assert 0.9 < float(aux) < 1.1
