"""PagePool allocator invariants under refcount/COW/rollback semantics.

Random reserve / fork / release / ensure_writable / ingest / truncate
traces must never leak a page, never double-free one, and never let a
shared page be written through any block table — and a token-granular
``truncate`` (the speculative-decoding rollback) must keep the refcount,
trie and LRU-retention invariants intact whether it merely rewinds the
partial tail page or also trims whole pages off the table. The trace
driver is deterministic given a seed; when ``hypothesis`` is installed
(CI) it also explores adversarial traces, and without it the seed sweep
still covers thousands of ops.
"""
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kv_cache import PagePool

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KV, HD, PS = 2, 8, 4
NUM_PAGES = 12
VOCAB = 5          # tiny alphabet → prompt prefixes collide often


def _pool():
    return PagePool(n_layers=1, n_kv_heads=KV, head_dim=HD,
                    num_pages=NUM_PAGES, page_size=PS, quantized=True)


def _apply_op(pool: PagePool, rng: random.Random, next_id: list,
              writers: dict) -> None:
    """One random allocator op; raises only for modeled-invalid requests."""
    resident = sorted(pool.tables)
    op = rng.choice(("reserve", "reserve", "fork", "release", "write",
                     "ingest", "truncate"))
    if op == "reserve":
        n_tokens = rng.randint(1, 3 * PS)
        prompt = [rng.randrange(VOCAB) for _ in range(n_tokens)]
        matched, shared = pool.match_prefix(prompt)
        if not pool.can_reserve(n_tokens, prompt=prompt):
            return
        sid = next_id[0]
        next_id[0] += 1
        got = pool.reserve(sid, n_tokens, prompt=prompt)
        assert got == matched
        assert pool.lens[sid] == matched
        if rng.random() < 0.7:     # most sequences publish their prefix
            pool.register_prefix(sid, prompt)
    elif op == "fork" and resident:
        parent = rng.choice(resident)
        if pool.num_free == 0:
            return                 # a forked child could deadlock on COW
        sid = next_id[0]
        next_id[0] += 1
        pool.fork(parent, sid)
        assert pool.tables[sid] == pool.tables[parent]
    elif op == "release" and resident:
        sid = rng.choice(resident)
        pool.release(sid)
        writers.pop(sid, None)
    elif op == "write" and resident:
        sid = rng.choice(resident)
        idx = rng.randrange(len(pool.tables[sid]))
        if pool.ref[pool.tables[sid][idx]] > 1 and not pool.free:
            return                 # COW copy needs a free slot
        slot = pool.ensure_writable(sid, idx)
        # the COW barrier's contract: post-write the slot is exclusive
        assert pool.ref[slot] == 1
        assert pool.tables[sid][idx] == slot
        # a slot never accepts writes from two different tables while shared
        holders = [s for s, t in pool.tables.items() if slot in t]
        assert holders == [sid]
        writers.setdefault(slot, set()).add(sid)
    elif op == "truncate" and resident:
        sid = rng.choice(resident)
        n = rng.randint(0, pool.lens[sid])
        trim = rng.random() < 0.5
        before = list(pool.tables[sid])
        pool.truncate(sid, n, drop_unused_pages=trim)
        assert pool.lens[sid] == n
        keep = pool.pages_for(n) if trim else len(before)
        assert pool.tables[sid] == before[:keep]
        for slot in before[keep:]:
            writers.pop(slot, None)    # dropped slots may be recycled
    elif op == "ingest" and resident:
        sid = rng.choice(resident)
        n_pages = len(pool.tables[sid])
        start_page = pool.lens[sid] // PS
        if start_page >= n_pages:
            return
        n_tok = rng.randint(1, (n_pages - start_page) * PS)
        if any(pool.ref[s] > 1
               for s in pool.tables[sid][start_page:start_page
                                         + pool.pages_for(n_tok)]):
            return                 # modeled-invalid: would write shared pages
        pool.ingest(sid, 0, jnp.ones((1, KV, n_tok, HD)),
                    jnp.ones((1, KV, n_tok, HD)), start=start_page * PS)


def _run_trace(seed, steps=120):
    rng = random.Random(seed)
    pool = _pool()
    next_id, writers = [0], {}
    for _ in range(steps):
        _apply_op(pool, rng, next_id, writers)
        pool.check_invariants()
    # draining everything must return the pool to pristine occupancy
    for sid in list(pool.tables):
        pool.release(sid)
    pool.check_invariants()
    assert pool.num_free == pool.num_pages
    assert sum(pool.ref) == 0


@pytest.mark.parametrize("seed", range(25))
def test_pool_trace_invariants(seed):
    _run_trace(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(st.integers(min_value=0, max_value=2**31))
    def test_pool_trace_invariants_hypothesis(seed):
        _run_trace(seed)


def test_cow_fork_preserves_parent_content():
    """A divergent write after fork copies the page; the parent's view,
    content and scales are untouched."""
    pool = _pool()
    k = jnp.asarray(np.random.default_rng(0).standard_normal((1, KV, PS, HD)),
                    jnp.float32)
    pool.reserve(0, PS)
    pool.ingest(0, 0, k, k)
    pool.fork(0, 1)
    parent_slot = pool.tables[0][0]
    assert pool.ref[parent_slot] == 2
    before = np.asarray(pool.k_pages[0][parent_slot])
    child_slot = pool.ensure_writable(1, 0)
    assert child_slot != parent_slot
    assert pool.ref[parent_slot] == 1 and pool.ref[child_slot] == 1
    # COW copied the page bit-exactly before the (upcoming) divergent write
    np.testing.assert_array_equal(np.asarray(pool.k_pages[0][child_slot]),
                                  before)
    pool.k_pages[0] = pool.k_pages[0].at[child_slot].set(0)
    np.testing.assert_array_equal(np.asarray(pool.k_pages[0][parent_slot]),
                                  before)
    pool.check_invariants()
    pool.release(0)
    pool.release(1)
    assert pool.num_free == pool.num_pages


def test_prefix_match_shares_and_release_retains():
    """Admission shares registered prefix pages; slots whose last reference
    dies are *retained* (trie intact) and revive on the next same-prefix
    reserve instead of re-prefilling."""
    pool = _pool()
    prompt = [1, 2, 3, 4, 1, 2, 3, 4, 9]           # two full pages + 1 token
    pool.reserve(0, len(prompt), prompt=prompt)
    pool.register_prefix(0, prompt)
    m, slots = pool.match_prefix(prompt)
    assert m == 2 * PS and slots == pool.tables[0][:2]
    # a second sequence with the same prompt shares both full pages
    got = pool.reserve(1, len(prompt) + 4, prompt=prompt)
    assert got == 2 * PS
    assert pool.tables[1][:2] == pool.tables[0][:2]
    assert all(pool.ref[s] == 2 for s in slots)
    pool.check_invariants()
    pool.release(0)
    assert all(pool.ref[s] == 1 for s in slots)    # still held by seq 1
    m2, _ = pool.match_prefix(prompt)
    assert m2 == 2 * PS                            # trie entry survives
    pool.release(1)
    m3, got3 = pool.match_prefix(prompt)
    assert m3 == 2 * PS and got3 == slots          # retained, not forgotten
    assert pool.num_retained == 2
    assert pool.num_free == pool.num_pages         # still fully reclaimable
    pool.check_invariants()
    # a re-submitted prompt revives the retained chain — same physical slots
    got = pool.reserve(2, len(prompt), prompt=prompt)
    assert got == 2 * PS and pool.tables[2][:2] == slots
    assert pool.num_retained == 0
    pool.release(2)
    pool.check_invariants()


def test_retention_disabled_frees_on_zero():
    """retain_pages=0 restores the PR-3 free-on-zero semantics."""
    pool = PagePool(n_layers=1, n_kv_heads=KV, head_dim=HD,
                    num_pages=NUM_PAGES, page_size=PS, quantized=True,
                    retain_pages=0)
    prompt = [1, 2, 3, 4, 9]
    pool.reserve(0, len(prompt), prompt=prompt)
    pool.register_prefix(0, prompt)
    pool.release(0)
    assert pool.match_prefix(prompt)[0] == 0       # forgotten immediately
    assert pool.num_retained == 0
    pool.check_invariants()


def test_retention_evicts_lru_under_pressure():
    """Retained pages are reclaimed LRU-first when the free list runs dry."""
    pool = _pool()
    # two released single-page prefixes, retained in submission order
    for sid, tok in enumerate((1, 2)):
        prompt = [tok] * PS + [9]                  # one full page + 1 token
        pool.reserve(sid, len(prompt), prompt=prompt)
        pool.register_prefix(sid, prompt)
    old_slot = pool.tables[0][0]
    new_slot = pool.tables[1][0]
    pool.release(0)
    pool.release(1)
    assert pool.num_retained == 2
    # exhaust the free list; the next alloc must evict seq 0's page first
    n_live = len(pool.free)
    pool.reserve(10, n_live * PS)
    assert not pool.free and pool.num_retained == 2
    pool.reserve(11, PS)                           # forces one LRU eviction
    assert pool.match_prefix([1] * PS + [9])[0] == 0      # oldest evicted
    assert pool.match_prefix([2] * PS + [9])[0] == PS     # newer retained
    assert pool.tables[11][0] == old_slot
    assert new_slot in pool._retained
    pool.check_invariants()


def test_truncate_rewind_and_trim_invariants():
    """Token-granular truncate rewinds the partial tail page as pure
    metadata; drop_unused_pages frees whole suffix pages back to the pool
    with refcount/trie/retention rules intact."""
    pool = _pool()
    s = 3 * PS + 2                                 # 4 pages, partial tail
    pool.reserve(0, s)
    pool.ingest(0, 0, jnp.ones((1, KV, s, HD)), jnp.ones((1, KV, s, HD)))
    table = list(pool.tables[0])
    # mid-page rewind (the speculative rollback): metadata only
    pool.truncate(0, 2 * PS + 1)
    assert pool.lens[0] == 2 * PS + 1
    assert pool.tables[0] == table                 # reservation kept
    pool.check_invariants()
    # and with page trimming: the suffix pages return to the free list
    free_before = pool.num_free
    pool.truncate(0, PS + 1, drop_unused_pages=True)
    assert pool.tables[0] == table[:2]             # ceil((PS+1)/PS) pages
    assert pool.num_free == free_before + 2
    pool.check_invariants()
    with pytest.raises(ValueError):
        pool.truncate(0, PS + 2)                   # can't truncate forward
    pool.release(0)
    pool.check_invariants()
    assert pool.num_free == pool.num_pages


def test_truncate_trim_respects_sharing_and_retention():
    """Trimmed slots follow release semantics: shared slots survive under
    their other holders; trie-indexed slots park in the retained LRU."""
    pool = _pool()
    prompt = [1, 2, 3, 4, 5, 6, 7, 8, 9]           # two full pages + 1
    pool.reserve(0, len(prompt) + PS, prompt=prompt)
    pool.lens[0] = len(prompt)                     # as if prefilled
    pool.register_prefix(0, prompt)
    pool.fork(0, 1)
    shared = list(pool.tables[0])
    # child rolls back past the shared suffix: parent's refs keep the slots
    pool.truncate(1, PS, drop_unused_pages=True)
    assert pool.tables[1] == shared[:1]
    assert all(pool.ref[s] >= 1 for s in shared)
    pool.check_invariants()
    pool.release(1)
    # parent rolls back past its own trie-registered page: the slot's last
    # reference dies -> retained (trie intact), not freed
    pool.truncate(0, PS, drop_unused_pages=True)
    assert pool.ref[shared[1]] == 0
    assert shared[1] in pool._retained
    assert pool.match_prefix(prompt)[0] == 2 * PS  # still shareable
    pool.check_invariants()
    pool.release(0)
    pool.check_invariants()


def test_rollback_then_write_crosses_cow():
    """After a rollback into a COW-shared page, the next write through
    ensure_writable forks the page instead of mutating the sharer's copy."""
    pool = _pool()
    k = jnp.asarray(np.random.default_rng(1).standard_normal((1, KV, PS, HD)),
                    jnp.float32)
    pool.reserve(0, 2 * PS)
    pool.ingest(0, 0, k, k)
    pool.fork(0, 1)
    slot = pool.tables[1][0]
    before = np.asarray(pool.k_pages[0][slot])
    pool.truncate(1, PS // 2)                      # rewind INTO a shared page
    assert pool.tables[1][0] == slot               # still shared after rewind
    new = pool.ensure_writable(1, 0)               # …until the next write
    assert new != slot and pool.ref[slot] == 1 and pool.ref[new] == 1
    np.testing.assert_array_equal(np.asarray(pool.k_pages[0][slot]), before)
    pool.check_invariants()
    pool.release(0)
    pool.release(1)
    assert pool.num_free == pool.num_pages


def test_match_prefix_capped_before_last_token():
    """A fully-matching prompt still leaves ≥1 token to prefill (the caller
    needs last-position logits to sample)."""
    pool = _pool()
    prompt = [1, 2, 3, 4, 5, 6, 7, 0]              # exactly two full pages
    pool.reserve(0, len(prompt), prompt=prompt)
    pool.register_prefix(0, prompt)
    m, slots = pool.match_prefix(prompt)
    assert m == PS and len(slots) == 1             # capped at (len-1)//ps
