"""Paged decode attention: Pallas kernel (interpret mode) vs XLA reference,
and reference vs the dense grouped-attention oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_reference)
from repro.models.attention import _grouped_attn

RNG = np.random.default_rng(0)


def _paged_int8(b, kv, ps, hd, num_pages, max_pages):
    kp = jnp.asarray(RNG.integers(-127, 128, (num_pages, kv, ps, hd)),
                     jnp.int8)
    vp = jnp.asarray(RNG.integers(-127, 128, (num_pages, kv, ps, hd)),
                     jnp.int8)
    ks = jnp.asarray(RNG.uniform(1e-3, 5e-2, (num_pages, kv, ps)),
                     jnp.float32)
    vs = jnp.asarray(RNG.uniform(1e-3, 5e-2, (num_pages, kv, ps)),
                     jnp.float32)
    tables = jnp.asarray(
        RNG.permutation(num_pages)[:b * max_pages].reshape(b, max_pages),
        jnp.int32)
    return kp, vp, ks, vs, tables


@pytest.mark.parametrize("b,kv,g,hd,ps,mp", [
    (3, 2, 3, 64, 16, 4),     # GQA
    (2, 1, 4, 32, 8, 5),      # MQA
    (1, 4, 1, 128, 16, 2),    # MHA
])
def test_kernel_matches_reference(b, kv, g, hd, ps, mp):
    kp, vp, ks, vs, tables = _paged_int8(b, kv, ps, hd, 32, mp)
    lengths = jnp.asarray(RNG.integers(1, mp * ps + 1, (b,)), jnp.int32)
    lengths = lengths.at[0].set(ps)          # exact page boundary
    q = jnp.asarray(RNG.standard_normal((b, kv, g, hd)), jnp.float32)
    ref = paged_attention_reference(q, kp, vp, ks, vs, tables, lengths)
    ker = paged_attention(q, kp, vp, ks, vs, tables, lengths,
                          impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_single_token_length():
    """length=1: only the first slot of the first page is attended."""
    b, kv, g, hd, ps, mp = 2, 2, 2, 32, 8, 3
    kp, vp, ks, vs, tables = _paged_int8(b, kv, ps, hd, 16, mp)
    lengths = jnp.ones((b,), jnp.int32)
    q = jnp.asarray(RNG.standard_normal((b, kv, g, hd)), jnp.float32)
    ref = paged_attention_reference(q, kp, vp, ks, vs, tables, lengths)
    ker = paged_attention(q, kp, vp, ks, vs, tables, lengths,
                          impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # softmax over one position ⇒ output is exactly that value row
    v0 = vp[tables[:, 0]].astype(jnp.float32) * vs[tables[:, 0]][..., None]
    np.testing.assert_allclose(np.asarray(ref),
                               np.tile(np.asarray(v0)[:, :, :1], (1, 1, g, 1)),
                               rtol=1e-5, atol=1e-5)


def test_reference_matches_dense_grouped_attn():
    """Float pages (no scales) against the model's dense attention oracle."""
    b, kv, g, hd, ps, mp = 2, 2, 2, 16, 8, 3
    t = mp * ps
    k_dense = jnp.asarray(RNG.standard_normal((b, t, kv, hd)), jnp.float32)
    v_dense = jnp.asarray(RNG.standard_normal((b, t, kv, hd)), jnp.float32)
    lengths = jnp.asarray([t - 3, ps], jnp.int32)
    # scatter the dense layout into pages row-by-row
    num_pages = b * mp
    tables = jnp.arange(num_pages, dtype=jnp.int32).reshape(b, mp)
    kp = jnp.swapaxes(k_dense.reshape(b, mp, ps, kv, hd), 2, 3).reshape(
        num_pages, kv, ps, hd)
    vp = jnp.swapaxes(v_dense.reshape(b, mp, ps, kv, hd), 2, 3).reshape(
        num_pages, kv, ps, hd)
    q = jnp.asarray(RNG.standard_normal((b, kv, g, hd)), jnp.float32)
    got = paged_attention_reference(q, kp, vp, None, None, tables, lengths)
    # dense oracle: decode-shaped _grouped_attn, whose k_len is a scalar fill
    # level — run it per sequence to emulate the ragged per-seq masking
    q5 = q.reshape(b, 1, kv, g, hd)
    outs = []
    for i in range(b):
        w = _grouped_attn(q5[i:i + 1], k_dense[i:i + 1], v_dense[i:i + 1],
                          q_pos=jnp.full((1,), t), k_pos=jnp.arange(t),
                          k_len=lengths[i])
        outs.append(w[:, 0])
    want = jnp.concatenate(outs, axis=0)               # (B, KV, G, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
