"""Chunked paged prefill: Pallas kernel (interpret) vs XLA reference,
reference vs the dense causal-attention oracle, chunk writes vs bulk
ingest, and end-to-end logits parity against the dense prefill path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_prefill import (paged_prefill_attention,
                                         paged_prefill_reference)
from repro.models import forward, init_params
from repro.models.attention import _grouped_attn
from repro.serving.engine import build_prefill_step, init_serve_caches
from repro.serving.kv_cache import PagePool

RNG = np.random.default_rng(0)


def _paged_int8(kv, ps, hd, num_pages, max_pages):
    kp = jnp.asarray(RNG.integers(-127, 128, (num_pages, kv, ps, hd)),
                     jnp.int8)
    vp = jnp.asarray(RNG.integers(-127, 128, (num_pages, kv, ps, hd)),
                     jnp.int8)
    ks = jnp.asarray(RNG.uniform(1e-3, 5e-2, (num_pages, kv, ps)),
                     jnp.float32)
    vs = jnp.asarray(RNG.uniform(1e-3, 5e-2, (num_pages, kv, ps)),
                     jnp.float32)
    table = jnp.asarray(RNG.permutation(num_pages)[:max_pages], jnp.int32)
    return kp, vp, ks, vs, table


@pytest.mark.parametrize("kv,g,hd,ps,pp,c,q_start", [
    (2, 3, 64, 16, 1, 16, 32),     # GQA, one page per grid step
    (2, 2, 32, 8, 4, 12, 24),      # multi-page steps, unaligned chunk end
    (1, 4, 16, 8, 2, 5, 0),        # MQA, chunk == whole (short) prompt
    (4, 1, 32, 16, 8, 32, 16),     # MHA, pages_per_step > n_pages
])
def test_kernel_matches_reference(kv, g, hd, ps, pp, c, q_start):
    mp = -(-(q_start + c) // ps) + 2
    kp, vp, ks, vs, table = _paged_int8(kv, ps, hd, 64, mp)
    q = jnp.asarray(RNG.standard_normal((kv, c, g, hd)), jnp.float32)
    ref = paged_prefill_reference(q, kp, vp, ks, vs, table, q_start=q_start)
    ker = paged_prefill_attention(q, kp, vp, ks, vs, table, q_start=q_start,
                                  pages_per_step=pp, impl="pallas",
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_reference_matches_dense_causal_oracle():
    """Float pages (no scales) against the model's chunked causal oracle."""
    kv, g, hd, ps, c, q_start = 2, 2, 16, 8, 12, 16
    t = q_start + c
    mp = -(-t // ps)
    k_dense = jnp.asarray(RNG.standard_normal((1, mp * ps, kv, hd)),
                          jnp.float32)
    v_dense = jnp.asarray(RNG.standard_normal((1, mp * ps, kv, hd)),
                          jnp.float32)
    table = jnp.arange(mp, dtype=jnp.int32)
    kp = jnp.swapaxes(k_dense.reshape(mp, ps, kv, hd), 1, 2)
    vp = jnp.swapaxes(v_dense.reshape(mp, ps, kv, hd), 1, 2)
    q = jnp.asarray(RNG.standard_normal((kv, c, g, hd)), jnp.float32)
    got = paged_prefill_reference(q, kp, vp, None, None, table,
                                  q_start=q_start)
    # oracle: q rows at positions [q_start, q_start+c) over the full dense KV
    q5 = jnp.transpose(q, (1, 0, 2, 3))[None]          # (1, C, KV, G, hd)
    want = _grouped_attn(q5, k_dense, v_dense,
                         q_pos=q_start + jnp.arange(c),
                         k_pos=jnp.arange(mp * ps),
                         k_len=jnp.int32(t))
    want = jnp.transpose(want[0], (1, 0, 2, 3))        # (KV, C, G, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_write_chunk_matches_bulk_ingest():
    """Page-aligned chunked writes quantize bit-identically to one bulk
    ingest — each page sees its exact f32 content exactly once."""
    kv, hd, ps, s = 2, 16, 8, 28
    k = jnp.asarray(RNG.standard_normal((1, kv, s, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, kv, s, hd)), jnp.float32)

    def fill(chunks):
        pool = PagePool(n_layers=1, n_kv_heads=kv, head_dim=hd, num_pages=8,
                        page_size=ps, quantized=True)
        pool.reserve(0, s)
        pos = 0
        for c in chunks:
            cache = pool.prefill_cache(0, 0, pos)
            cache = cache.write_chunk(k[:, :, pos:pos + c],
                                      v[:, :, pos:pos + c])
            pool.writeback(0, cache)
            pos += c
        return pool

    bulk = PagePool(n_layers=1, n_kv_heads=kv, head_dim=hd, num_pages=8,
                    page_size=ps, quantized=True)
    bulk.reserve(0, s)
    bulk.ingest(0, 0, k, v)
    for chunks in ((8, 8, 8, 4), (16, 12), (24, 4)):
        pool = fill(chunks)
        for slot_c, slot_b in zip(pool.tables[0], bulk.tables[0]):
            np.testing.assert_array_equal(
                np.asarray(pool.k_pages[0][slot_c]),
                np.asarray(bulk.k_pages[0][slot_b]))
            np.testing.assert_array_equal(
                np.asarray(pool.k_scale[0][slot_c]),
                np.asarray(bulk.k_scale[0][slot_b]))


def _chunked_paged_prefill(cfg, params, toks, pool, seq_id, chunk, pp=2):
    """Drive forward() chunk by chunk through PagedPrefillCache views,
    exactly like the engine — returns the last-position logits."""
    s = toks.shape[1]
    pos, logits = 0, None
    while pos < s:
        c = min(chunk, s - pos)
        if c < s - pos:
            c -= c % pool.page_size
        caches = [{"attn": pool.prefill_cache(i, seq_id, pos, pp)}
                  for i in range(cfg.n_layers)]
        logits, new_caches, _ = forward(
            params, cfg, toks[:, pos:pos + c],
            positions=(pos + jnp.arange(c))[None],
            caches=caches, last_logits_only=True)
        for i, layer in enumerate(new_caches):
            pool.writeback(i, layer["attn"])
        pool.lens[seq_id] = pos + c
        pos += c
    return logits[:, -1]


@pytest.mark.parametrize("chunk", [8, 16, 28])
def test_chunked_paged_prefill_matches_dense_prefill(chunk):
    """Acceptance: paged chunked prefill tracks the dense prefill path's
    logits within int8-quantization tolerance, for any chunking."""
    cfg = get_config("qwen2-0.5b", reduced=True, dtype="float32",
                     n_heads=4, n_kv_heads=2, head_dim=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    s, ps = 28, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0,
                              cfg.vocab_size)
    dense = init_serve_caches(cfg, 1, s)
    last_dense, _ = build_prefill_step(cfg)(params, toks, dense)

    pool = PagePool(n_layers=cfg.n_layers, n_kv_heads=2, head_dim=cfg.hd,
                    num_pages=8, page_size=ps, quantized=True,
                    dtype=jnp.float32)
    pool.reserve(0, s)
    last_paged = _chunked_paged_prefill(cfg, params, toks, pool, 0, chunk)
    np.testing.assert_allclose(np.asarray(last_paged, np.float32),
                               np.asarray(last_dense, np.float32),
                               rtol=3e-2, atol=3e-2)
    assert (np.argmax(np.asarray(last_paged), -1)
            == np.argmax(np.asarray(last_dense), -1)).all()
