"""Hypothesis property tests for the system's numeric invariants.

``hypothesis`` is an optional dev dependency (see requirements-dev.txt); this
module skips cleanly when it is absent instead of erroring test collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import camp, hybrid, quant
from repro.kernels import ref

_dims = st.integers(min_value=1, max_value=48)
_even_dims = st.integers(min_value=1, max_value=24).map(lambda x: 2 * x)


@settings(deadline=None, max_examples=40)
@given(m=_dims, k=_even_dims, n=_dims, seed=st.integers(0, 2**31 - 1))
def test_int8_matmul_exact_vs_int64(m, k, n, seed):
    """int32 accumulation never overflows/differs from exact int64 math for
    CAMP-sized K (the paper's overflow-handling claim)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-127, 128, (m, k)).astype(np.int8)
    b = rng.integers(-127, 128, (k, n)).astype(np.int8)
    got = np.asarray(ref.dot_i32(jnp.asarray(a), jnp.asarray(b)))
    exact = a.astype(np.int64) @ b.astype(np.int64)
    assert (np.abs(exact) < 2**31).all()          # k ≤ 48·127² < 2^31
    np.testing.assert_array_equal(got, exact.astype(np.int32))


@settings(deadline=None, max_examples=40)
@given(m=_dims, k=_even_dims, n=_dims, seed=st.integers(0, 2**31 - 1))
def test_hybrid_identity_random_matrices(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-128, 128, (m, k)).astype(np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (k, n)).astype(np.int8))
    np.testing.assert_array_equal(np.asarray(hybrid.hybrid_matmul_i8(a, b)),
                                  np.asarray(ref.dot_i32(a, b)))


@settings(deadline=None, max_examples=40)
@given(rows=_dims, k=_even_dims, seed=st.integers(0, 2**31 - 1),
       scale_pow=st.integers(-8, 8))
def test_quant_roundtrip_error_bound(rows, k, seed, scale_pow):
    """|x - dequant(quant(x))| ≤ scale/2 per element (symmetric rounding)."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, k)) * 10.0 ** scale_pow).astype(np.float32)
    q, s = quant.quantize_rowwise(jnp.asarray(x), bits=8)
    back = np.asarray(quant.dequantize_rowwise(q, s))
    bound = np.asarray(s) / 2 + 1e-30
    assert (np.abs(back - x) <= bound + 1e-6 * np.abs(x)).all()


@settings(deadline=None, max_examples=40)
@given(k=_even_dims, n=_dims, seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(k, n, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, (k, n)).astype(np.int8)
    rt = np.asarray(quant.unpack_int4(quant.pack_int4(jnp.asarray(q))))
    np.testing.assert_array_equal(rt, q)


@settings(deadline=None, max_examples=25)
@given(m=st.integers(1, 16), k=st.integers(2, 32).map(lambda x: 2 * x),
       n=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_quantized_gemm_error_scales_with_quantization_step(m, k, n, seed):
    """CAMP w8a8 output error is bounded by the first-order quantization
    noise model: |err| ≲ K·(sa·sb)/2 terms (loose 4× slack)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    wq = camp.prepare_weight(jnp.asarray(w), "w8a8")
    y = np.asarray(camp.camp_matmul(jnp.asarray(x), wq, qmode="w8a8",
                                    out_dtype=jnp.float32))
    exact = x @ w
    sa = np.abs(x).max(axis=1, keepdims=True) / 127.0
    sb = np.asarray(wq.scale)
    bound = 4.0 * k * (sa / 2 + 1e-12) * np.maximum(np.abs(w).max(), 1.0) \
        + 4.0 * k * (sb / 2) * np.maximum(np.abs(x).max(), 1.0)
    assert (np.abs(y - exact) <= bound + 1e-4).all()


@settings(deadline=None, max_examples=20)
@given(s=st.integers(2, 8).map(lambda x: 8 * x), seed=st.integers(0, 2**31 - 1),
       chunk=st.sampled_from([8, 16, 32]))
def test_wkv6_chunked_equals_sequential(s, seed, chunk):
    from repro.models.rwkv import _wkv6_chunked, wkv6_sequential_ref
    rng = np.random.default_rng(seed)
    b, h, hd = 2, 2, 8
    r, k, v = (jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
               for _ in range(3))
    lw = -jnp.clip(jnp.exp(jnp.asarray(
        rng.standard_normal((b, s, h, hd)), jnp.float32)), 1e-4, 2.5)
    u = jnp.asarray(rng.standard_normal((h, hd)) * 0.1, jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((b, h, hd, hd)), jnp.float32)
    c = min(chunk, s)
    while s % c:
        c -= 1
    y_c, st_c = _wkv6_chunked(r, k, v, lw, u, s0, c)
    y_r, st_r = wkv6_sequential_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r),
                               rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=20)
@given(s=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
def test_mamba_scan_equals_sequential(s, seed):
    from repro.models.ssm import _ssm_scan_segment
    rng = np.random.default_rng(seed)
    b, di, n = 2, 4, 3
    a = jnp.exp(-jnp.exp(jnp.asarray(rng.standard_normal((b, s, di, n)),
                                     jnp.float32)))
    bu = jnp.asarray(rng.standard_normal((b, s, di, n)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, di, n)), jnp.float32)
    h_all, h_last = _ssm_scan_segment(a, bu, h0)
    h = h0
    for t in range(s):
        h = a[:, t] * h + bu[:, t]
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=2e-5, atol=2e-5)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 4))
def test_int8_adam_moments_track_fp32(seed, steps):
    """Quantized-moment AdamW stays close to exact AdamW over a few steps."""
    from repro.optim import adamw
    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)}
    o_ref = adamw(lr=1e-2, quantize_moments=False, grad_clip_norm=None)
    o_q = adamw(lr=1e-2, quantize_moments=True, grad_clip_norm=None)
    s_ref, s_q = o_ref.init(p), o_q.init(p)
    p_ref, p_q = p, p
    for i in range(steps):
        g = {"w": jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)}
        u_ref, s_ref = o_ref.update(g, s_ref, p_ref)
        u_q, s_q = o_q.update(g, s_q, p_q)
        p_ref = jax.tree.map(lambda a, b: a + b, p_ref, u_ref)
        p_q = jax.tree.map(lambda a, b: a + b, p_q, u_q)
    np.testing.assert_allclose(np.asarray(p_q["w"]), np.asarray(p_ref["w"]),
                               rtol=0.15, atol=5e-3)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2**31 - 1))
def test_moe_dropless_capacity_saturates(seed):
    """Beyond the drop-free point (cf = E/k), raising capacity cannot change
    the output — every token already got all its k experts."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.moe import init_moe, moe_ffn
    cfg = get_config("moonshot-v1-16b-a3b", reduced=True)
    key = jax.random.PRNGKey(seed)
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    cf_free = cfg.moe_experts / cfg.moe_top_k
    y1, _ = moe_ffn(p, dataclasses.replace(cfg, moe_capacity_factor=cf_free), x)
    y2, _ = moe_ffn(p, dataclasses.replace(cfg, moe_capacity_factor=2 * cf_free), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)
