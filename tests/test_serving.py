"""Serving engine: generation determinism, quantized-vs-bf16 agreement,
int8 KV cache accuracy, batched requests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_params, quantize_params
from repro.serving.engine import (build_decode_step, build_prefill_step,
                                  generate, init_serve_caches)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen2-0.5b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generate_deterministic_greedy(model):
    cfg, params = model
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    t1 = generate(params, cfg, prompt, steps=8)
    t2 = generate(params, cfg, prompt, steps=8)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_quantized_generation_close(model):
    """w8a8 serving should track bf16 greedy decoding for most tokens."""
    cfg, params = model
    prompt = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                cfg.vocab_size)
    base = np.asarray(generate(params, cfg, prompt, steps=8))
    qp = quantize_params(params, cfg, "w8a8")
    q = np.asarray(generate(qp, cfg, prompt, steps=8, ))
    agree = (base == q).mean()
    assert agree > 0.5, f"w8a8 token agreement only {agree:.2f}"


def test_int8_kv_cache_close_to_bf16(model):
    cfg, params = model
    b, s = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    pre = build_prefill_step(cfg)
    caches_bf = init_serve_caches(cfg, b, 32)
    caches_i8 = init_serve_caches(cfg, b, 32, kv_dtype="int8")
    logits_bf, caches_bf = pre(params, toks, caches_bf)
    logits_i8, caches_i8 = pre(params, toks, caches_i8)
    # prefill logits identical (cache not read during prefill)
    np.testing.assert_allclose(np.asarray(logits_bf, np.float32),
                               np.asarray(logits_i8, np.float32), rtol=1e-2,
                               atol=1e-2)
    dec = build_decode_step(cfg)
    tok = jnp.argmax(logits_bf, -1)[:, None].astype(jnp.int32)
    t_bf, _ = dec(params, caches_bf, tok, jnp.int32(s))
    t_i8, _ = dec(params, caches_i8, tok, jnp.int32(s))
    assert (np.asarray(t_bf) == np.asarray(t_i8)).mean() >= 0.5


def test_prefill_last_logits_match_full_forward(model):
    cfg, params = model
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                              cfg.vocab_size)
    full, _, _ = forward(params, cfg, toks)
    pre = build_prefill_step(cfg)
    last, _ = pre(params, toks, init_serve_caches(cfg, 2, 16))
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_batched_requests_isolated(model):
    """Each batch row's generation must only depend on its own prompt."""
    cfg, params = model
    p1 = jax.random.randint(jax.random.PRNGKey(5), (1, 16), 0, cfg.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(6), (1, 16), 0, cfg.vocab_size)
    both = jnp.concatenate([p1, p2], axis=0)
    solo = np.asarray(generate(params, cfg, p1, steps=6))
    batched = np.asarray(generate(params, cfg, both, steps=6))
    np.testing.assert_array_equal(batched[0], solo[0])


def test_temperature_sampling_runs(model):
    cfg, params = model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0,
                                cfg.vocab_size)
    toks = generate(params, cfg, prompt, steps=4, key=jax.random.PRNGKey(0),
                    sample="temperature", temperature=0.8)
    assert toks.shape == (2, 4)
    assert (np.asarray(toks) >= 0).all()
    assert (np.asarray(toks) < cfg.vocab_size).all()
