"""Serving engine: generation determinism, quantized-vs-bf16 agreement,
int8 KV cache accuracy, batched requests, paged-int8 decode parity and
continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_params, quantize_params
from repro.serving.engine import (ContinuousBatchingEngine, build_decode_step,
                                  build_prefill_step, generate,
                                  init_serve_caches)
from repro.serving.kv_cache import PagePool


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen2-0.5b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generate_deterministic_greedy(model):
    cfg, params = model
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    t1 = generate(params, cfg, prompt, steps=8)
    t2 = generate(params, cfg, prompt, steps=8)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_quantized_generation_close(model):
    """w8a8 serving should track bf16 greedy decoding for most tokens."""
    cfg, params = model
    prompt = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                cfg.vocab_size)
    base = np.asarray(generate(params, cfg, prompt, steps=8))
    qp = quantize_params(params, cfg, "w8a8")
    q = np.asarray(generate(qp, cfg, prompt, steps=8, ))
    agree = (base == q).mean()
    assert agree > 0.5, f"w8a8 token agreement only {agree:.2f}"


def test_int8_kv_cache_close_to_bf16(model):
    cfg, params = model
    b, s = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    pre = build_prefill_step(cfg)
    caches_bf = init_serve_caches(cfg, b, 32)
    caches_i8 = init_serve_caches(cfg, b, 32, kv_dtype="int8")
    logits_bf, caches_bf = pre(params, toks, caches_bf)
    logits_i8, caches_i8 = pre(params, toks, caches_i8)
    # prefill logits identical (cache not read during prefill)
    np.testing.assert_allclose(np.asarray(logits_bf, np.float32),
                               np.asarray(logits_i8, np.float32), rtol=1e-2,
                               atol=1e-2)
    dec = build_decode_step(cfg)
    tok = jnp.argmax(logits_bf, -1)[:, None].astype(jnp.int32)
    t_bf, _ = dec(params, caches_bf, tok, jnp.int32(s))
    t_i8, _ = dec(params, caches_i8, tok, jnp.int32(s))
    assert (np.asarray(t_bf) == np.asarray(t_i8)).mean() >= 0.5


def test_prefill_last_logits_match_full_forward(model):
    cfg, params = model
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                              cfg.vocab_size)
    full, _, _ = forward(params, cfg, toks)
    pre = build_prefill_step(cfg)
    last, _ = pre(params, toks, init_serve_caches(cfg, 2, 16))
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_batched_requests_isolated(model):
    """Each batch row's generation must only depend on its own prompt."""
    cfg, params = model
    p1 = jax.random.randint(jax.random.PRNGKey(5), (1, 16), 0, cfg.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(6), (1, 16), 0, cfg.vocab_size)
    both = jnp.concatenate([p1, p2], axis=0)
    solo = np.asarray(generate(params, cfg, p1, steps=6))
    batched = np.asarray(generate(params, cfg, both, steps=6))
    np.testing.assert_array_equal(batched[0], solo[0])


@pytest.mark.parametrize("n_kv", [1, 2, 4])   # MQA / GQA / MHA
def test_paged_int8_decode_parity_vs_f32_dense(n_kv):
    """Paged int8-KV decode logits track the dense f32-cache reference."""
    cfg = get_config("qwen2-0.5b", reduced=True, dtype="float32",
                     n_heads=4, n_kv_heads=n_kv, head_dim=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s, steps, ps = 2, 12, 4, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    caches = init_serve_caches(cfg, b, s + steps)            # dense f32
    last, caches = build_prefill_step(cfg)(params, toks, caches)

    pool = PagePool(n_layers=cfg.n_layers, n_kv_heads=n_kv, head_dim=cfg.hd,
                    num_pages=4 * b * ((s + steps) // ps + 1), page_size=ps,
                    quantized=True, dtype=jnp.float32)
    for row in range(b):
        pool.reserve(row, s + steps)
        for i, layer in enumerate(caches):
            pool.ingest(row, i, layer["attn"].k[row:row + 1, :, :s],
                        layer["attn"].v[row:row + 1, :, :s])

    tok = jnp.argmax(last.astype(jnp.float32), -1)[:, None].astype(jnp.int32)
    for step in range(steps):
        logits_d, caches, _ = forward(params, cfg, tok, caches=caches,
                                      cache_pos=jnp.int32(s + step))
        tables, lengths = pool.batch_tables(list(range(b)))
        pcaches = [{"attn": pool.layer_cache(i, tables, lengths)}
                   for i in range(cfg.n_layers)]
        logits_p, new_p, _ = forward(params, cfg, tok,
                                     positions=lengths[:, None],
                                     caches=pcaches)
        for i, layer in enumerate(new_p):
            pool.writeback(i, layer["attn"])
        for row in range(b):
            pool.lens[row] += 1
        np.testing.assert_allclose(
            np.asarray(logits_p, np.float32), np.asarray(logits_d, np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"n_kv={n_kv} decode step {step}")
        # drive both paths with the same next token
        tok = jnp.argmax(logits_d[:, -1].astype(jnp.float32),
                         -1)[:, None].astype(jnp.int32)


def test_continuous_batching_mixed_trace_matches_solo(model):
    """Sequences entering/leaving mid-flight decode exactly as when alone."""
    cfg, params = model
    specs = [(5, 6), (12, 4), (8, 10), (3, 3), (16, 5)]     # (prompt, max_new)
    prompts = [jax.random.randint(jax.random.PRNGKey(10 + i), (n,), 0,
                                  cfg.vocab_size)
               for i, (n, _) in enumerate(specs)]

    def make_engine():
        # 8 pages of 8 tokens < the 11 pages the trace needs in total →
        # admission is staggered and relies on mid-flight page reclamation
        return ContinuousBatchingEngine(params, cfg, kv_dtype="int8",
                                        page_size=8, capacity_tokens=64)

    eng = make_engine()
    sids = [eng.submit(prompts[i], mx) for i, (_, mx) in enumerate(specs)]
    mixed = eng.run()
    assert set(mixed) == set(sids)
    for i, (n, mx) in enumerate(specs):
        assert len(mixed[sids[i]]) == mx
        solo_eng = make_engine()
        sid = solo_eng.submit(prompts[i], mx)
        solo = solo_eng.run()[sid]
        assert mixed[sids[i]] == solo, f"request {i} diverged under batching"
    assert eng.pool.num_free == eng.pool.num_pages   # all pages reclaimed


def test_prefix_sharing_page_accounting_and_parity(model):
    """N sequences sharing a P-token prefix hold ~P/page_size shared pages
    (not N·P/page_size), decode identically to unshared solo runs, and
    release everything on finish."""
    cfg, params = model
    n, prefix_len, tail_len, ps = 4, 32, 8, 8
    prefix = jax.random.randint(jax.random.PRNGKey(20), (prefix_len,), 0,
                                cfg.vocab_size)
    prompts = [jnp.concatenate([
        prefix, jax.random.randint(jax.random.PRNGKey(21 + i), (tail_len,),
                                   0, cfg.vocab_size)]) for i in range(n)]

    eng = ContinuousBatchingEngine(params, cfg, kv_dtype="int8", page_size=ps,
                                   capacity_tokens=8 * 64)
    sids = [eng.submit(p, 16) for p in prompts]
    while eng.waiting or eng.prefilling:          # drive until all admitted
        eng.step()
    stats = eng.pool.shared_page_stats()
    shared_pages = prefix_len // ps
    assert stats["shared_slots"] == shared_pages
    # n tables reference the prefix chain; (n-1)·P/ps pages were saved
    assert (stats["table_entries"] - stats["distinct_slots"]
            == (n - 1) * shared_pages)
    outs = eng.run()
    assert eng.pool.num_free == eng.pool.num_pages    # decref'd clean
    for i, sid in enumerate(sids):
        solo = ContinuousBatchingEngine(params, cfg, kv_dtype="int8",
                                        page_size=ps, capacity_tokens=8 * 64)
        ssid = solo.submit(prompts[i], 16)
        assert solo.run()[ssid] == outs[sid], f"request {i} diverged"


def test_engine_rejects_oversized_request():
    cfg = get_config("qwen2-0.5b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, kv_dtype="int8",
                                   page_size=8, capacity_tokens=16)
    eng.submit(jnp.zeros((8,), jnp.int32), 32)       # needs 5 pages, pool has 2
    with pytest.raises(RuntimeError):
        eng.run()


def test_temperature_sampling_runs(model):
    cfg, params = model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0,
                                cfg.vocab_size)
    toks = generate(params, cfg, prompt, steps=4, key=jax.random.PRNGKey(0),
                    sample="temperature", temperature=0.8)
    assert toks.shape == (2, 4)
    assert (np.asarray(toks) >= 0).all()
    assert (np.asarray(toks) < cfg.vocab_size).all()
