"""Speculative decoding: drafters, exact acceptance–rejection, and
draft–verify engine parity.

The load-bearing guarantees:

* greedy spec-decode token streams are **bit-identical** to non-speculative
  decode for any drafter (n-gram, a strong draft model, an adversarially
  bad draft model) — token-granular write-once pages make the verify panel
  read exactly the bytes sequential decode would have read, and the
  rollback leaves exactly the bytes sequential decode would have written;
* temperature sampling **preserves the target distribution** — verified by
  a frequency test of the acceptance–rejection operator on a tiny vocab
  (draft sampled from q → emitted marginal equals softmax(target/T));
* page accounting stays clean through speculation (reservation respected,
  all pages reclaimed at the end).

The sharded (tp) variant of the greedy parity check lives in
``tests/tp_parity_check.py`` (SPEC_OK marker) under 8 virtual devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.spec_decode import (NGramDrafter, SpecConfig,
                                       _softmax, accept_speculative)

CFG = get_config("qwen2-0.5b", n_layers=2, d_model=64, n_heads=4,
                 n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
                 max_seq_len=256, dtype="float32")
PS = 8


@pytest.fixture(scope="module")
def model():
    return CFG, init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def prompts():
    pat = jax.random.randint(jax.random.PRNGKey(1), (6,), 0, CFG.vocab_size)
    return [jnp.tile(pat, 6),                      # repetitive: drafts land
            jax.random.randint(jax.random.PRNGKey(2), (17,), 0,
                               CFG.vocab_size)]    # random: drafts miss


def _run(params, spec, prompts, *, max_new=14, sample="greedy",
         temperature=1.0, key=None):
    eng = ContinuousBatchingEngine(params, CFG, kv_dtype="int8", page_size=PS,
                                   capacity_tokens=2048, spec=spec,
                                   sample=sample, temperature=temperature,
                                   key=key)
    sids = [eng.submit(p, max_new) for p in prompts]
    outs = eng.run()
    return [outs[s] for s in sids], eng


# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------
def test_ngram_drafter_prompt_lookup():
    d = NGramDrafter(max_n=3, min_n=1)
    h = [5, 6, 7, 8, 9, 5, 6, 7]
    toks, q = d.propose(0, h, 3)
    assert toks == [8, 9, 5] and q is None         # 3-gram [5,6,7] continues
    toks, _ = d.propose(0, h, 2)
    assert toks == [8, 9]                          # gamma caps the proposal
    assert d.propose(0, [1, 2, 3], 4) == ([], None)  # nothing recurs
    # most recent occurrence wins
    toks, _ = d.propose(0, [1, 9, 1, 4, 1], 1)
    assert toks == [4]


# ---------------------------------------------------------------------------
# Greedy parity: spec streams bit-identical to the plain engine
# ---------------------------------------------------------------------------
def test_greedy_parity_ngram(model, prompts):
    cfg, params = model
    base, _ = _run(params, None, prompts)
    spec, eng = _run(params, SpecConfig(method="ngram", gamma=3), prompts)
    assert spec == base
    s = eng.spec_summary()
    assert s["proposed"] > 0                       # drafting actually ran
    # every token beyond each request's first (prefill-sampled) one came
    # out of a verify step
    assert s["emitted"] == sum(len(t) - 1 for t in spec)
    assert eng.pool.num_free == eng.pool.num_pages


def test_greedy_parity_strong_draft_model(model, prompts):
    """Self-drafting (draft == target) accepts nearly everything — the
    multi-token fast path — and still matches the plain stream exactly."""
    cfg, params = model
    base, _ = _run(params, None, prompts)
    spec_cfg = SpecConfig(method="draft", gamma=3, draft_cfg=cfg,
                          draft_params=params)
    spec, eng = _run(params, spec_cfg, prompts)
    assert spec == base
    s = eng.spec_summary()
    assert s["acceptance_rate"] > 0.9
    assert s["mean_tokens_per_step"] > 2.0
    # per-request stats add up to the engine totals
    per = s["per_request"].values()
    assert sum(p["proposed"] for p in per) == s["proposed"]
    assert sum(p["accepted"] for p in per) == s["accepted"]
    assert eng.pool.num_free == eng.pool.num_pages
    assert eng.drafter.pool.num_free == eng.drafter.pool.num_pages


def test_greedy_parity_bad_draft_model(model, prompts):
    """An unrelated draft model is rejected nearly always — every emitted
    token comes from a full rollback — and parity still holds bit-exactly,
    which is the hardest exercise of truncate."""
    cfg, params = model
    dcfg = get_config("qwen2-0.5b", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=1, head_dim=16, d_ff=64, vocab_size=256,
                      max_seq_len=256, dtype="float32")
    dparams = init_params(jax.random.PRNGKey(7), dcfg)
    base, _ = _run(params, None, prompts)
    spec, eng = _run(params, SpecConfig(method="draft", gamma=3,
                                        draft_cfg=dcfg, draft_params=dparams),
                     prompts)
    assert spec == base
    s = eng.spec_summary()
    assert s["proposed"] > 0
    assert s["acceptance_rate"] < 0.5
    assert eng.pool.num_free == eng.pool.num_pages


def test_spec_respects_token_budget_and_reservation(model, prompts):
    """max_new is hit exactly even when the window exceeds the remaining
    budget (gamma is clipped, never the emitted count)."""
    cfg, params = model
    for max_new in (1, 2, 3, 5):
        base, _ = _run(params, None, prompts, max_new=max_new)
        spec, eng = _run(params, SpecConfig(method="ngram", gamma=4), prompts,
                         max_new=max_new)
        assert spec == base
        assert all(len(t) == max_new for t in spec)
        assert eng.pool.num_free == eng.pool.num_pages


# ---------------------------------------------------------------------------
# Temperature: exact distribution preservation
# ---------------------------------------------------------------------------
def test_acceptance_rejection_preserves_target_distribution():
    """Frequency test on a tiny vocab: with drafts sampled from q, the
    first emitted token's marginal equals softmax(target/T) — the
    speculative-sampling theorem, exercised through the real operator."""
    rng = np.random.default_rng(0)
    v, gamma, temp, n = 12, 2, 0.8, 3000
    rows = (rng.standard_normal((gamma + 1, v)) * 2).astype(np.float32)
    q = _softmax(rng.standard_normal((gamma, v)).astype(np.float32))
    p0 = _softmax(rows[0] / temp)
    counts = np.zeros(v)
    for s in range(n):
        draft = [int(rng.choice(v, p=q[i])) for i in range(gamma)]
        _, emitted = accept_speculative(
            rows, draft, q, sample="temperature", temperature=temp,
            key=jax.random.PRNGKey(s), seq_id=0, start_index=0)
        counts[emitted[0]] += 1
    tv = 0.5 * np.abs(counts / n - p0).sum()
    assert tv < 0.06, f"total variation {tv:.3f}"
    # deterministic (one-hot q) drafter: same theorem, q = delta(draft)
    counts = np.zeros(v)
    for s in range(n):
        _, emitted = accept_speculative(
            rows, [3, 5], None, sample="temperature", temperature=temp,
            key=jax.random.PRNGKey(s), seq_id=1, start_index=4)
        counts[emitted[0]] += 1
    tv = 0.5 * np.abs(counts / n - p0).sum()
    assert tv < 0.06, f"total variation {tv:.3f} (one-hot)"


def test_acceptance_rejection_greedy_matches_argmax():
    rng = np.random.default_rng(3)
    rows = rng.standard_normal((4, 9)).astype(np.float32)
    argm = [int(r.argmax()) for r in rows]
    # full acceptance + bonus
    n, em = accept_speculative(rows, argm[:3], None, sample="greedy",
                               temperature=1.0, key=jax.random.PRNGKey(0),
                               seq_id=0, start_index=0)
    assert (n, em) == (3, argm)
    # first mismatch replaced by the target argmax, suffix dropped
    bad = [argm[0], (argm[1] + 1) % 9, argm[2]]
    n, em = accept_speculative(rows, bad, None, sample="greedy",
                               temperature=1.0, key=jax.random.PRNGKey(0),
                               seq_id=0, start_index=0)
    assert (n, em) == (1, argm[:2])


def test_temperature_spec_runs_and_is_deterministic(model, prompts):
    cfg, params = model
    spec = SpecConfig(method="ngram", gamma=3)
    t1, e1 = _run(params, spec, prompts, sample="temperature",
                  temperature=0.9, key=jax.random.PRNGKey(5))
    t2, _ = _run(params, spec, prompts, sample="temperature",
                 temperature=0.9, key=jax.random.PRNGKey(5))
    assert t1 == t2                                # same key → same stream
    assert all(0 <= t < cfg.vocab_size for toks in t1 for t in toks)
    assert e1.pool.num_free == e1.pool.num_pages


# ---------------------------------------------------------------------------
# Scheduling details
# ---------------------------------------------------------------------------
def test_spec_with_prefix_sharing_and_mixed_admission(model):
    """Speculation composes with trie prefix sharing and staggered
    admission: same streams as the plain engine, pages reclaimed."""
    cfg, params = model
    prefix = jax.random.randint(jax.random.PRNGKey(20), (2 * PS,), 0,
                                cfg.vocab_size)
    prompts = [jnp.concatenate([
        prefix, jax.random.randint(jax.random.PRNGKey(30 + i), (4 + 3 * i,),
                                   0, cfg.vocab_size)]) for i in range(3)]
    base, _ = _run(params, None, prompts, max_new=8)
    spec, eng = _run(params, SpecConfig(method="ngram", gamma=2), prompts,
                     max_new=8)
    assert spec == base
    assert eng.pool.num_free == eng.pool.num_pages


def test_auto_gamma_retunes_from_acceptance(model, prompts, tmp_path,
                                            monkeypatch):
    from repro.core import autotune
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    autotune.clear_cache()
    cfg, params = model
    base, _ = _run(params, None, prompts, max_new=48)
    spec_cfg = SpecConfig(method="draft", gamma="auto", draft_cfg=cfg,
                          draft_params=params)
    spec, eng = _run(params, spec_cfg, prompts, max_new=48)
    assert spec == base                            # parity across re-picks
    # self-drafting acceptance ~1 → the autotuner moves to a wide window
    assert eng.spec_totals.steps >= eng.SPEC_RETUNE_EVERY
    assert eng.spec_gamma == max(autotune.SPEC_GAMMAS)
    autotune.clear_cache()
