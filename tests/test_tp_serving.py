"""Tensor-parallel serving: serve-mode sharding rules, TP shard logic,
autotune-warmup dedupe, and the 8-virtual-device parity suite.

The multi-device checks (sharded vs single-device decode/prefill logits,
engine page accounting, indivisible-head fallback) live in
``tests/tp_parity_check.py`` and run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the repo
convention for device-count overrides (they must not leak into the pytest
session). The in-process tests below need no mesh devices at all.
"""
import os
import subprocess
import sys

from jax.sharding import PartitionSpec as P

from repro.core import autotune
from repro.parallel.sharding import make_rules, serve_tp, spec_for


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = _FakeMesh({"data": 2, "model": 4})


def test_serve_rules_shard_kv_heads_not_seq():
    rules = make_rules("serve")
    # paged pool pages: (P, KV, ps, hd) — kv heads carry model, pages don't
    spec = spec_for((64, 4, 8, 16), ("kv_pages", "kv_heads", None, None),
                    rules, MESH)
    assert spec == P(None, "model", None, None)
    # decode q (B, KV, G, hd)
    spec = spec_for((8, 4, 2, 16), ("batch", "kv_heads", None, "head_dim"),
                    rules, MESH)
    assert spec == P("data", "model", None, None)
    # serve mode never splits the KV sequence dim (pages are head-sharded)
    spec = spec_for((8, 4096, 16), ("batch", "seq_kv", None), rules, MESH)
    assert spec == P("data", None, None)


def test_serve_rules_indivisible_heads_replicate():
    rules = make_rules("serve")
    spec = spec_for((64, 3, 8, 16), ("kv_pages", "kv_heads", None, None),
                    rules, MESH)
    assert spec == P(None, None, None, None)


def test_serve_tp_inactive_without_context():
    mesh, tp = serve_tp()
    assert mesh is None and tp == 1


def test_tp_shardable_packed_int4():
    import jax.numpy as jnp
    from repro.core.camp import prepare_weight
    from repro.models.modules import tp_shardable

    w = jnp.zeros((24, 16), jnp.float32)
    assert tp_shardable(w, 4)                    # 24 % 4 == 0
    assert not tp_shardable(w, 5)
    w4 = prepare_weight(w, "w4a8")
    assert tp_shardable(w4, 4)                   # 6 logical rows/shard, even
    assert not tp_shardable(w4, 8)               # 3 rows/shard: splits a pack
    w4b = prepare_weight(jnp.zeros((20, 16), jnp.float32), "w4a8")
    assert tp_shardable(w4b, 2)                  # 10/shard, pack-aligned
    assert not tp_shardable(w4b, 4)              # 5/shard: splits a pack


def test_warm_gemm_autotune_dedupes_and_warms_tp_shards(tmp_path,
                                                        monkeypatch):
    from repro.configs import get_config
    from repro.serving.engine import warm_gemm_autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    autotune.clear_cache()
    cfg = get_config("qwen2-0.5b", reduced=True, qmode="w8a8",
                     n_heads=8, n_kv_heads=4)
    tuned = warm_gemm_autotune(cfg, batch_sizes=(1, 8))
    assert tuned
    # the same warmup again is a no-op: every shape is already cached
    assert warm_gemm_autotune(cfg, batch_sizes=(1, 8)) == []
    # tp=2 warms the *shard* shapes (on a fresh cache so none collide with
    # the replicated shapes above): row-parallel wo runs K/2, column-
    # parallel q/kv proj run N/2
    autotune.clear_cache(disk=True)
    tp_tuned = warm_gemm_autotune(cfg, batch_sizes=(1, 8), tp=2)
    kns = {(k, n) for ((m, n, k), _) in tp_tuned}
    d, hhd, kvhd = cfg.d_model, cfg.n_heads * cfg.hd, cfg.n_kv_heads * cfg.hd
    assert (hhd // 2, d) in kns                  # wo row shard
    assert (d, kvhd // 2) in kns                 # kv column shard
    assert (hhd, d) not in kns                   # unsharded wo NOT warmed
    # and repeating the tp warmup is also fully deduped
    assert warm_gemm_autotune(cfg, batch_sizes=(1, 8), tp=2) == []
    autotune.clear_cache()


def test_engine_without_mesh_is_single_device():
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = get_config("qwen2-0.5b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(params, cfg, kv_dtype="int8", page_size=8,
                                   capacity_tokens=64)
    assert eng.tp == 1 and eng.mesh is None and not eng.pool.sharded


def test_tp_parity_subprocess():
    """Sharded decode + prefill logits parity, engine page accounting and
    the indivisible-head fallback, on an 8-virtual-device CPU mesh."""
    script = os.path.join(os.path.dirname(__file__), "tp_parity_check.py")
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    res = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, timeout=520, env=env)
    for marker in ("PREFILL_OK", "DECODE_OK", "ENGINE_OK", "INDIV_OK",
                   "QUANT_OK", "SPEC_OK", "TP_PARITY_OK"):
        assert marker in res.stdout, \
            (marker, res.stdout[-1000:], res.stderr[-3000:])
