"""Training-loop behaviour: loss descends, restart-from-checkpoint is exact,
straggler monitor flags injected stalls, grad-accum is consistent."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.optim import adamw
from repro.train import build_train_step, init_train_state
from repro.train import loop as loop_lib


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-0.6b", reduced=True)
    opt = adamw(lr=3e-3)
    step = build_train_step(cfg, opt)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    data = SyntheticLMData(cfg.vocab_size, 8, 32, seed=0)
    return cfg, opt, step, state, data


def _copy(state):
    # loop_lib.run donates its input state; tests sharing the fixture must
    # pass a private copy.
    return jax.tree.map(jnp.array, state)


def test_loss_decreases(setup):
    _, _, step, state, data = setup
    state, hist = loop_lib.run(step, _copy(state), data, steps=30, log_every=0)
    assert np.mean(hist["loss"][-5:]) < np.mean(hist["loss"][:5]) - 0.2


def test_restart_exact(tmp_path, setup):
    cfg, opt, step, _, data = setup
    s0 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    full, hist_full = loop_lib.run(step, s0, data, steps=20, log_every=0)

    s1 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    s1, _ = loop_lib.run(step, s1, data, steps=10, ckpt_dir=tmp_path,
                         ckpt_every=10, log_every=0)
    # new "process": restore from step 10 and continue to 20
    s2 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    s2, hist2 = loop_lib.run(step, s2, data, steps=20, ckpt_dir=tmp_path,
                             ckpt_every=100, log_every=0)
    np.testing.assert_allclose(
        np.asarray(full["params"]["final_norm"], np.float32),
        np.asarray(s2["params"]["final_norm"], np.float32), rtol=1e-5)
    assert len(hist2["loss"]) == 10  # only replayed steps 10..20


def test_straggler_monitor_flags_stall(setup):
    _, _, step, state, data = setup
    # calibrate the stall against this machine's (possibly loaded) step time
    state, warm = loop_lib.run(step, _copy(state), data, steps=6, log_every=0)
    base = max(float(np.median(warm["step_time"][2:])), 0.01)
    stall = max(1.0, 8.0 * base)
    orig = data.batch_at

    class SlowData:
        hit = False

        def batch_at(self, s):
            if s == 15 and not SlowData.hit:
                SlowData.hit = True
                time.sleep(stall)
            return orig(s)

    state, hist = loop_lib.run(step, state, SlowData(), steps=20,
                               log_every=0, straggler_factor=3.0)
    assert 15 in hist["straggler_steps"]


def test_straggler_monitor_unit():
    mon = loop_lib.StragglerMonitor(factor=3.0, warmup=1)
    flagged = [mon.observe(i, dt) for i, dt in
               enumerate([60.0, 0.1, 0.11, 0.09, 0.1, 0.5, 0.1])]
    # 60s compile (warmup) must not poison; the 0.5s stall is flagged
    assert flagged == [False, False, False, False, False, True, False]


def test_grad_accum_matches_full_batch(setup):
    cfg, opt, _, _, _ = setup
    data = SyntheticLMData(cfg.vocab_size, 8, 16, seed=3)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s_a = init_train_state(jax.random.PRNGKey(1), cfg, opt)
    s_b = jax.tree.map(lambda x: x, s_a)
    step1 = build_train_step(cfg, opt, grad_accum=1)
    step4 = build_train_step(cfg, opt, grad_accum=4)
    s_a, m_a = jax.jit(step1)(s_a, batch)
    s_b, m_b = jax.jit(step4)(s_b, batch)
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(s_a["params"]["final_norm"], np.float32),
        np.asarray(s_b["params"]["final_norm"], np.float32),
        rtol=1e-4, atol=1e-5)


def test_int8_grad_compression_trains(setup):
    cfg, _, _, _, data = setup
    opt = adamw(lr=3e-3)
    step = build_train_step(cfg, opt, compress_grads="int8")
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    state, hist = loop_lib.run(step, state, data, steps=25, log_every=0)
    assert np.mean(hist["loss"][-5:]) < np.mean(hist["loss"][:5]) - 0.15


def test_data_pipeline_deterministic():
    d1 = SyntheticLMData(512, 4, 16, seed=9)
    d2 = SyntheticLMData(512, 4, 16, seed=9)
    for s in (0, 3, 1000):
        np.testing.assert_array_equal(d1.batch_at(s)["inputs"],
                                      d2.batch_at(s)["inputs"])
    assert not np.array_equal(d1.batch_at(0)["inputs"],
                              d1.batch_at(1)["inputs"])
