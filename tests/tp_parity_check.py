"""Sharded-vs-single-device serving parity driver (run as a script).

Spawned by ``tests/test_tp_serving.py`` (and by the CI sharded-serving job)
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the mesh has
8 virtual CPU devices; the device-count override must never leak into the
pytest session, hence the subprocess. Checks, in order:

* PREFILL_OK — every chunk's last-position logits of a chunked paged
  prefill match between a head-sharded pool under the serve mesh context
  (shard_map kernels, interpret-mode Pallas) and a replicated pool.
* DECODE_OK  — per-step ragged decode logits match the same way.
* ENGINE_OK  — a mixed continuous-batching workload (prefix sharing,
  staggered admission) produces identical tokens AND bit-identical pool
  accounting (block tables, lens, shared-page stats, free/retained counts).
* INDIV_OK   — a kv-head count indivisible by the model axis degrades to
  replicated attention (engine tp == 1, pool unsharded) with identical
  tokens.
* QUANT_OK   — the quantized TP GEMM paths: ``row_parallel_linear`` with an
  int8 and a packed-int4 QuantizedTensor (K-shard slicing) tracks the
  single-device fused CAMP result, ``quantized_psum`` is exact to one
  shared quantization step, and a w8a8 engine with ``tp_int8_reduce`` keeps
  majority token agreement with its single-device run.
* SPEC_OK    — speculative decoding under the mesh: the γ+1-token verify
  panels run through the head-sharded ``paged_prefill_attention_tp`` path
  (drafting stays replicated), greedy token streams and draft/accept stats
  are identical to both the sharded non-speculative engine and the
  single-device speculative engine, and rollback leaves the replicated
  page accounting bit-for-bit equal.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_serving_mesh
from repro.models import init_params
from repro.models.transformer import forward
from repro.parallel.sharding import make_rules, mesh_context
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.kv_cache import PagePool

PS = 8          # page size
CHUNK = 16      # prefill chunk (page-aligned)
STEPS = 4       # decode steps in the manual loop
TP = 4

CFG = get_config("qwen2-0.5b", n_layers=2, d_model=64, n_heads=8,
                 n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
                 max_seq_len=128, dtype="float32")
PARAMS = init_params(jax.random.PRNGKey(0), CFG)
MESH = make_serving_mesh(TP)
RULES = make_rules("serve")


def serve_scope():
    return mesh_context(MESH, RULES, mode="serve")


def make_pool(mesh):
    return PagePool(n_layers=CFG.n_layers, n_kv_heads=CFG.n_kv_heads,
                    head_dim=CFG.hd, num_pages=64, page_size=PS,
                    quantized=True, dtype=jnp.float32, mesh=mesh)


def chunked_prefill(pool, prompt, scope):
    """Engine-shaped chunked paged prefill; returns each chunk's last
    logits."""
    sid = 0
    s = int(prompt.shape[0])
    pool.reserve(sid, s + STEPS)
    outs, pos = [], 0
    while pos < s:
        c = min(CHUNK, s - pos)
        toks = prompt[None, pos:pos + c]
        positions = (pos + jnp.arange(c))[None]
        caches = [{"attn": pool.prefill_cache(i, sid, pos, 2)}
                  for i in range(CFG.n_layers)]
        with scope():
            lg, new, _ = forward(PARAMS, CFG, toks, positions=positions,
                                 caches=caches, last_logits_only=True)
        for i, layer in enumerate(new):
            pool.writeback(i, layer["attn"])
        pool.lens[sid] = pos + c
        outs.append(np.asarray(lg[:, -1], np.float32))
        pos += c
    return outs


def decode_steps(pool, tok, scope):
    """Manual ragged decode loop; returns per-step logits."""
    outs = []
    for _ in range(STEPS):
        pool.ensure_writable(0, pool.lens[0] // PS)
        tables, lengths = pool.batch_tables([0])
        caches = [{"attn": pool.layer_cache(i, tables, lengths)}
                  for i in range(CFG.n_layers)]
        with scope():
            lg, new, _ = forward(PARAMS, CFG, tok, positions=lengths[:, None],
                                 caches=caches)
        for i, layer in enumerate(new):
            pool.writeback(i, layer["attn"])
        pool.lens[0] += 1
        last = np.asarray(lg[:, -1], np.float32)
        outs.append(last)
        tok = jnp.asarray(last.argmax(-1)[:, None], jnp.int32)
    return outs


def check_prefill_decode():
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3 * CHUNK - 4,), 0,
                                CFG.vocab_size)
    pool_r = make_pool(None)
    pool_s = make_pool(MESH)
    assert pool_s.sharded
    shards = pool_s.k_pages[0].addressable_shards
    assert {tuple(sh.data.shape) for sh in shards} == \
        {(64, CFG.n_kv_heads // TP, PS, CFG.hd)}, "pages not head-sharded"

    ref = chunked_prefill(pool_r, prompt, contextlib.nullcontext)
    got = chunked_prefill(pool_s, prompt, serve_scope)
    for i, (a, b) in enumerate(zip(ref, got)):
        np.testing.assert_allclose(b, a, rtol=2e-4, atol=2e-4,
                                   err_msg=f"prefill chunk {i}")
    print("PREFILL_OK")

    tok = jnp.asarray(ref[-1].argmax(-1)[:, None], jnp.int32)
    ref_d = decode_steps(pool_r, tok, contextlib.nullcontext)
    got_d = decode_steps(pool_s, tok, serve_scope)
    for i, (a, b) in enumerate(zip(ref_d, got_d)):
        np.testing.assert_allclose(b, a, rtol=2e-4, atol=2e-4,
                                   err_msg=f"decode step {i}")
    print("DECODE_OK")


def engine_state(eng):
    """The replicated host-side accounting that must match bit-for-bit."""
    return {
        "tables": dict(eng.pool.tables),
        "lens": dict(eng.pool.lens),
        "stats": eng.pool.shared_page_stats(),
        "free": eng.pool.num_free,
        "retained": eng.pool.num_retained,
    }


def run_engine(cfg, params, prompts, mesh, *, snap_at: int):
    eng = ContinuousBatchingEngine(params, cfg, kv_dtype="int8", page_size=PS,
                                   capacity_tokens=512, mesh=mesh)
    sids = [eng.submit(p, 6) for p in prompts]
    snap = None
    steps = 0
    while eng.step():
        steps += 1
        if steps == snap_at:
            snap = engine_state(eng)
    outs = {s: eng.finished[s].tokens for s in sids}
    return outs, snap, engine_state(eng), eng


def check_engine():
    key = jax.random.PRNGKey(2)
    prefix = jax.random.randint(key, (2 * PS,), 0, CFG.vocab_size)
    prompts = [jnp.concatenate([
        prefix,
        jax.random.randint(jax.random.fold_in(key, i), (5 + 3 * i,), 0,
                           CFG.vocab_size)]) for i in range(3)]
    ref, ref_mid, ref_end, _ = run_engine(CFG, PARAMS, prompts, None,
                                          snap_at=4)
    got, got_mid, got_end, eng = run_engine(CFG, PARAMS, prompts, MESH,
                                            snap_at=4)
    assert eng.tp == TP and eng.pool.sharded
    assert ref == got, f"tokens diverged: {ref} vs {got}"
    assert ref_mid == got_mid, "mid-flight page accounting diverged"
    assert ref_end == got_end, "final page accounting diverged"
    assert ref_mid["stats"]["shared_slots"] > 0, "prefix sharing inactive"
    assert ref_end["retained"] > 0, "trie retention inactive after release"
    print("ENGINE_OK")


def check_indivisible():
    cfg = get_config("qwen2-0.5b", n_layers=2, d_model=60, n_heads=6,
                     n_kv_heads=3, head_dim=16, d_ff=128, vocab_size=512,
                     max_seq_len=128, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [jax.random.randint(jax.random.PRNGKey(9 + i), (10 + 3 * i,),
                                  0, cfg.vocab_size) for i in range(2)]
    ref, _, ref_end, _ = run_engine(cfg, params, prompts, None, snap_at=2)
    got, _, got_end, eng = run_engine(cfg, params, prompts, MESH, snap_at=2)
    assert eng.tp == 1 and not eng.pool.sharded, \
        "3 kv heads must degrade to replicated under model=4"
    assert ref == got and ref_end == got_end
    print("INDIV_OK")


def check_quantized():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.camp import prepare_weight
    from repro.models import quantize_params
    from repro.models.modules import linear, row_parallel_linear
    from repro.parallel.collectives import quantized_psum

    rng = np.random.default_rng(5)
    # quantized_psum: exact integer sum, one shared quantization step
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    f = shard_map(lambda a: quantized_psum(a, "model"), mesh=MESH,
                  in_specs=P(None, "model"), out_specs=P(None, None),
                  check_rep=False)
    want = x.reshape(16, TP, 32 // TP).transpose(1, 0, 2).sum(0)
    step = float(jnp.max(jnp.abs(x))) / 127.0
    assert np.abs(np.asarray(f(x)) - np.asarray(want)).max() <= TP * step

    # row_parallel_linear on QuantizedTensor weights (int8 and packed int4,
    # exercising the K-shard slicing of the packed payload), with and
    # without the int8-wire reduce, vs the single-device fused CAMP GEMM
    xx = jnp.asarray(rng.standard_normal((3, 5, 64)), jnp.float32)
    for qmode in ("w8a8", "w4a8"):
        wq = prepare_weight(
            jnp.asarray(rng.standard_normal((64, 32)), jnp.float32), qmode)
        ref = np.asarray(linear(xx, wq, qmode=qmode), np.float32)
        span = np.abs(ref).max()
        for wire in (False, True):
            got = np.asarray(row_parallel_linear(
                xx, wq, mesh=MESH, qmode=qmode, quantized_reduce=wire),
                np.float32)
            assert np.abs(got - ref).max() <= 0.05 * span, \
                f"{qmode} wire={wire}: rel err {np.abs(got-ref).max()/span}"

    # w8a8 engine end to end with the int8-compressed all-reduce
    cfg = get_config("qwen2-0.5b", n_layers=2, d_model=64, n_heads=8,
                     n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
                     max_seq_len=128, qmode="w8a8")
    params = quantize_params(init_params(jax.random.PRNGKey(0), cfg), cfg,
                             "w8a8")
    prompts = [jax.random.randint(jax.random.PRNGKey(30 + i), (10 + 4 * i,),
                                  0, cfg.vocab_size) for i in range(2)]

    def run(mesh, wire):
        eng = ContinuousBatchingEngine(params, cfg, kv_dtype="int8",
                                       page_size=PS, capacity_tokens=512,
                                       mesh=mesh, tp_int8_reduce=wire)
        sids = [eng.submit(p, 6) for p in prompts]
        outs = eng.run()
        return [t for s in sids for t in outs[s]], eng

    ref, _ = run(None, False)
    got, eng = run(MESH, True)
    assert eng.tp == TP and eng.pool.sharded
    agree = np.mean([a == b for a, b in zip(ref, got)])
    assert agree >= 0.5, f"w8a8+int8-wire token agreement {agree}"
    print("QUANT_OK")


def check_spec():
    from repro.serving.spec_decode import SpecConfig

    key = jax.random.PRNGKey(4)
    pattern = jax.random.randint(key, (6,), 0, CFG.vocab_size)
    prompts = [jnp.tile(pattern, 5),                 # repetitive: drafts land
               jax.random.randint(jax.random.fold_in(key, 1), (13,), 0,
                                  CFG.vocab_size)]   # random: drafts miss

    def run(mesh, spec):
        eng = ContinuousBatchingEngine(PARAMS, CFG, kv_dtype="int8",
                                       page_size=PS, capacity_tokens=512,
                                       mesh=mesh, spec=spec)
        sids = [eng.submit(p, 10) for p in prompts]
        outs = eng.run()
        return [outs[s] for s in sids], engine_state(eng), eng

    spec = lambda: SpecConfig(method="ngram", gamma=3)  # noqa: E731
    base, base_end, _ = run(None, None)
    ref, ref_end, ref_eng = run(None, spec())
    got, got_end, eng = run(MESH, spec())
    assert eng.tp == TP and eng.pool.sharded
    assert ref == base, "single-device spec diverged from plain decode"
    assert got == ref, f"sharded spec tokens diverged: {ref} vs {got}"
    assert got_end == ref_end == base_end, "page accounting diverged"
    r, g = ref_eng.spec_summary(), eng.spec_summary()
    assert r == g, f"spec stats diverged: {r} vs {g}"
    assert g["proposed"] > 0 and g["accepted"] > 0, "speculation inactive"
    print("SPEC_OK")


if __name__ == "__main__":
    assert len(jax.devices()) >= 8, "needs 8 virtual devices (XLA_FLAGS)"
    check_prefill_decode()
    check_engine()
    check_indivisible()
    check_quantized()
    check_spec()
    print("TP_PARITY_OK")
